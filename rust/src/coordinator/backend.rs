//! Execution backends behind the coordinator: the native engine and the
//! PJRT AOT artifacts share one `Backend` trait so the serving loop,
//! benches and examples are backend-agnostic.
//!
//! The trait is shaped around a **persistent slot pool** (continuous
//! batching): `open_batch` allocates a decode surface with `capacity`
//! slots, `prefill_slot` admits one request into a free slot,
//! `decode` steps only the occupied slots, and `release_slot` frees a
//! finished slot so a queued request can be admitted mid-flight.
//!
//! Backends advertise how liberal their admission discipline is via
//! [`Backend::continuous`]:
//!
//! * [`NativeBackend`] — fully continuous: any free slot can be refilled
//!   at any time. [`Backend::decode`] steps every listed slot through
//!   **one weight-stationary batched engine step**
//!   ([`NativeEngine::step_batch`]): quantized weights stream once per
//!   step across all occupied slots instead of once per slot
//!   ([`NativeBackend::with_sequential_decode`] restores the per-slot
//!   baseline for A/B benching). By default every batch runs on a
//!   **paged KV pool**
//!   ([`crate::engine::kv::KvPagePool`]): slots map fixed-size pages on
//!   demand (resident bytes track true sequence length, pages-in-use is
//!   the admission-pressure signal), prompts sharing a cached prefix map
//!   the same read-only pages, and [`Backend::max_batch`] is the
//!   configurable [`NativeBackend::with_max_slots`] — decoupled from any
//!   compiled lane count. [`NativeBackend::with_dense`] restores the
//!   one-dense-`KvCache`-per-slot baseline.
//!   [`NativeBackend::with_speculative`] adds **self-speculative
//!   decoding**: slots draft up to K tokens on the degraded branch and
//!   verify them all in one multi-position pass
//!   ([`Backend::decode_speculative`], see [`crate::spec`]) — greedy
//!   slots under argmax acceptance (token-identical output), sampled
//!   slots under rejection-sampling acceptance (distribution-identical
//!   output), with optional per-slot adaptive draft depth.
//! * [`PjrtBackend`] in **per-lane** mode (`with_per_lane(true)`) — each
//!   slot is an independent batch-1 surface with its own position
//!   counter, so admission is continuous too (per-slot position
//!   tracking; mid-flight prefill falls back to single-step chunks when
//!   the prompt remainder is smaller than the compiled chunk sizes).
//! * [`PjrtBackend`] in **lock-step** mode (default) — one shared
//!   batch-N surface. The compiled artifacts carry a *scalar* `pos0`
//!   shared by every lane, so all lanes advance together: admission is
//!   only possible into a fresh surface with one shared prompt length
//!   (the aligned groups the `Batcher` forms). Released/empty lanes are
//!   masked: they are fed a dummy token whose logits and KV writes are
//!   never read by any occupied lane (lanes are independent in the
//!   batch dimension). Recompiling the artifacts with a per-lane
//!   position vector would lift this restriction — see ROADMAP.

use super::request::{GenRequest, SamplingParams};
use super::sampler::distribution;
use crate::engine::kv::{
    KvPagePool, KvPoolConfig, KvPoolStats, KvSlot, PagedKv, PagedKvRef, PagedSlotBatch, ParkedKv,
    SlotBatch,
};
use crate::engine::native::{EngineWs, RowsWant, SlotLogits};
use crate::engine::{KvCache, NativeEngine, SubMode};
use crate::model::{Config, WeightStore};
use crate::runtime::exec::{build_weight_feed, Value};
use crate::runtime::{ExecRegistry, LoadedExec, Manifest};
use crate::spec::{
    draft_tokens, greedy_accept_ids, stochastic_accept_with, DraftKv, DraftMode, KController,
    SpecDecoder, SpecStep, SpeculativeConfig,
};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// The last sampled token of an occupied slot, fed back for one decode
/// step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotToken {
    pub slot: usize,
    pub token: u32,
}

/// One slot's input to a speculative step: its last sampled token plus
/// the request's sampling params. Greedy params (`temperature <= 0`)
/// select argmax acceptance; sampled params select rejection-sampling
/// acceptance under the same temperature / top-k / top-p the plain
/// decode path would sample with — so speculation preserves the output
/// distribution exactly (see `crate::spec::accept`).
#[derive(Debug, Clone)]
pub struct SpecSlot {
    pub slot: usize,
    pub token: u32,
    pub sampling: SamplingParams,
}

impl SpecSlot {
    /// Greedy-request convenience (argmax acceptance).
    pub fn greedy(slot: usize, token: u32) -> SpecSlot {
        SpecSlot { slot, token, sampling: SamplingParams::default() }
    }
}

/// One per-slot PJRT surface (batch-1 artifacts, own position counter).
#[derive(Debug, Clone)]
pub struct PjrtLane {
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    pos: usize,
}

/// Per-batch generation state (opaque to the serving loop).
pub enum BatchState {
    /// Native engine, dense baseline: one independent full-capacity KV
    /// cache per occupied slot.
    Native { slots: Vec<Option<KvCache>> },
    /// Native engine, paged (default): one shared page pool, one paged
    /// view per occupied slot. Dropping the state drops the pool (and
    /// with it the prefix cache), so a serving run's reuse scope is its
    /// own pool.
    NativePaged { pool: KvPagePool, slots: Vec<Option<PagedKv>> },
    /// PJRT lock-step surface: shared KV buffers and a scalar position.
    Pjrt {
        kv_k: Vec<f32>,
        kv_v: Vec<f32>,
        pos: usize,
        capacity: usize,
        occupied: Vec<bool>,
        decoded: bool,
    },
    /// PJRT per-lane surfaces: independent batch-1 KV + position per slot.
    PjrtLanes { lanes: Vec<Option<PjrtLane>> },
}

/// A preempted slot's full engine-side state, detached from any batch:
/// the target KV (bit-exact copy of the committed positions), the
/// speculative draft mirror when the slot had one, the mirror's lazy
/// catch-up queue, and the adaptive-K controller. Produced by
/// [`Backend::swap_out`]; [`Backend::swap_in`] restores it into a free
/// slot such that subsequent decode output is bit-identical to a run
/// that was never preempted.
pub struct ParkedSlot {
    target: ParkedKv,
    draft: Option<ParkedKv>,
    pending: Vec<u32>,
    ctrl: Option<KController>,
}

impl ParkedSlot {
    /// Committed target positions held by this parking buffer.
    pub fn len(&self) -> usize {
        self.target.len()
    }

    pub fn is_empty(&self) -> bool {
        self.target.is_empty()
    }

    /// Host bytes held while parked (swap accounting).
    pub fn bytes(&self) -> usize {
        self.target.bytes() + self.draft.as_ref().map_or(0, |d| d.bytes())
    }
}

pub trait Backend {
    fn cfg(&self) -> &Config;

    /// Largest compiled/supported slot count.
    fn max_batch(&self) -> usize;

    /// Whether a freed slot can be refilled while other slots keep
    /// decoding. Non-continuous backends only admit into a fresh surface
    /// (no decode steps yet) with one shared prompt length.
    fn continuous(&self) -> bool;

    /// Open a decode surface with `capacity` empty slots.
    fn open_batch(&mut self, capacity: usize) -> Result<BatchState>;

    /// Admit `prompt` into the free slot `slot`; returns the last-position
    /// logits (the distribution of the first generated token).
    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>>;

    /// Admit several equal-length prompts at once into distinct free
    /// slots of a fresh surface. Lock-step backends override this with a
    /// single batched prefill; the default loops [`Backend::prefill_slot`].
    fn prefill_slots(
        &mut self,
        state: &mut BatchState,
        admissions: &[(usize, &[u32])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(admissions.len());
        for &(slot, prompt) in admissions {
            out.push(self.prefill_slot(state, slot, prompt)?);
        }
        Ok(out)
    }

    /// Reserve whatever `slot` needs for its next decode step (for the
    /// paged native backend: the KV page the next position lands in,
    /// copy-on-write included). The serving loop calls this per slot
    /// before the batched [`Backend::decode`]; an error means the slot
    /// cannot advance (e.g. pool exhausted) and the loop finishes that
    /// one request with a terminal error instead of aborting.
    fn prepare_decode(&mut self, _state: &mut BatchState, _slot: usize) -> Result<()> {
        Ok(())
    }

    /// One decode step over the listed occupied slots: `tokens[i]` names a
    /// slot and its last sampled token. Returns next-token logits per
    /// entry, in the same order. Unlisted slots are untouched (native,
    /// per-lane) or masked (lock-step). Slots must have been
    /// [`Backend::prepare_decode`]d this step.
    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>>;

    /// Speculative-decoding configuration when this backend drafts and
    /// verifies its own tokens (None = plain decode only).
    fn speculative(&self) -> Option<SpeculativeConfig> {
        None
    }

    /// One **speculative** step over the listed occupied slots: each
    /// slot drafts up to K tokens on its degraded branch, verifies all
    /// of them (plus the input token) in one multi-position batched
    /// pass, and commits `1..=K+1` tokens ([`SpecStep`]). Greedy slots
    /// use argmax acceptance (committed stream token-identical to
    /// non-speculative greedy decode); sampled slots use
    /// rejection-sampling acceptance (committed stream distributed
    /// exactly as plain sampled decode). Only meaningful when
    /// [`Backend::speculative`] returns a config; a slot must be driven
    /// by either this or [`Backend::decode`] for its whole lifetime,
    /// never both (the draft KV mirrors the target step for step).
    fn decode_speculative(
        &mut self,
        _state: &mut BatchState,
        _reqs: &[SpecSlot],
    ) -> Result<Vec<SpecStep>> {
        bail!("backend {} does not support speculative decoding", self.name())
    }

    /// Draft and verify wall time (nanoseconds) accumulated by the
    /// speculative path since the last call, consumed by the serving
    /// loop's per-phase latency histograms after each
    /// [`Backend::decode_speculative`]. Backends that don't meter their
    /// phases return `(0, 0)` (the loop treats zero as "not measured").
    fn take_step_phases(&mut self) -> (u64, u64) {
        (0, 0)
    }

    /// Cumulative persistent-weight read bytes (target plus draft), when
    /// the backend meters traffic. The serving loop snapshots this into
    /// [`super::metrics::ServeMetrics`] so weight bytes per generated
    /// token are reportable per run.
    fn weight_bytes(&self) -> Option<u64> {
        None
    }

    /// Whether this backend supports preemption via
    /// [`Backend::swap_out`] / [`Backend::swap_in`].
    fn preemptible(&self) -> bool {
        false
    }

    /// Swap the occupied `slot` out into a host-side [`ParkedSlot`] and
    /// free the slot (paged KV pages return to the pool — that is the
    /// point: swap-out frees the memory another admission needs).
    fn swap_out(&mut self, _state: &mut BatchState, _slot: usize) -> Result<ParkedSlot> {
        bail!("backend {} does not support preemption", self.name())
    }

    /// Restore a parked slot into the free slot `slot` bit-exactly. On
    /// error the surface is left unchanged and `parked` remains valid,
    /// so the caller can retry once pressure eases.
    fn swap_in(&mut self, _state: &mut BatchState, _slot: usize, _parked: &ParkedSlot)
        -> Result<()> {
        bail!("backend {} does not support preemption", self.name())
    }

    /// Load-adaptive degradation: cap every slot's speculative draft
    /// window at `cap` drafts per step (None lifts the cap). Capping at
    /// 0 degrades speculation to plain verify steps without touching
    /// the draft mirrors, so lifting the cap resumes drafting exactly.
    /// A no-op for backends without speculation.
    fn set_spec_k_cap(&mut self, _cap: Option<usize>) {}

    /// Load-adaptive degradation: drop to the bare quantized branch
    /// (sub-branch correction off) while `bare` is true; restoring
    /// brings the saved sub-branch mode back. A no-op for backends
    /// without a sub-branch.
    fn set_bare_branch(&mut self, _bare: bool) {}

    /// Load-adaptive degradation: route `slot`'s plain decode through a
    /// lower-bit shadow engine (`on = true`) or back through the full
    /// engine. The shadow shares the slot's KV geometry, so flipping
    /// mid-flight keeps the stream valid (though not bit-identical to
    /// the undegraded run). Errors when unsupported.
    fn set_slot_shadow(&mut self, _slot: usize, _on: bool) -> Result<()> {
        bail!("backend {} does not support shadow degradation", self.name())
    }

    /// Whether `slot` currently decodes through the shadow engine.
    fn slot_shadowed(&self, _slot: usize) -> bool {
        false
    }

    /// Free `slot` so a queued request can be admitted into it.
    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()>;

    /// KV-pool counters for this batch, when the backend serves from a
    /// paged pool (None on dense/PJRT surfaces). The serving loop folds
    /// these into [`super::metrics::ServeMetrics`].
    fn kv_stats(&self, _state: &BatchState) -> Option<KvPoolStats> {
        None
    }

    fn name(&self) -> String;
}

/// Per-request admission validation against model limits.
pub fn validate_request(cfg: &Config, req: &GenRequest) -> Result<()> {
    if req.prompt.is_empty() {
        bail!("request {}: empty prompt", req.id);
    }
    if req.prompt.len() + req.max_new_tokens > cfg.max_seq {
        bail!(
            "request {}: prompt {} + gen {} exceeds max_seq {}",
            req.id,
            req.prompt.len(),
            req.max_new_tokens,
            cfg.max_seq
        );
    }
    Ok(())
}

/// Validate an aligned batch of requests against backend limits
/// (lock-step group admission).
pub fn validate_batch(backend: &dyn Backend, reqs: &[GenRequest]) -> Result<()> {
    if reqs.len() > backend.max_batch() {
        bail!(
            "batch of {} requests exceeds backend max batch {}",
            reqs.len(),
            backend.max_batch()
        );
    }
    let Some(first) = reqs.first() else { return Ok(()) };
    let plen = first.prompt.len();
    for r in reqs {
        validate_request(backend.cfg(), r)?;
        if r.prompt.len() != plen {
            bail!("batch is not prompt-length aligned");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Positions per KV page unless overridden by
/// [`NativeBackend::with_kv_pool`].
pub const DEFAULT_PAGE_SIZE: usize = 16;

pub struct NativeBackend {
    engine: NativeEngine,
    ws: EngineWs,
    label: String,
    /// paged pool (default) vs one dense cache per slot
    paged: bool,
    /// slot-pool width advertised as `max_batch` — decoupled from any
    /// compiled lane count on the native path
    max_slots: usize,
    page_size: usize,
    /// pool size in pages; 0 = worst case (`capacity * max_seq` worth,
    /// so decode can never exhaust the pool mid-flight)
    pool_pages: usize,
    /// A/B escape hatch: decode each listed slot with its own engine
    /// step (re-streaming the weights per slot) instead of the
    /// weight-stationary batched step.
    sequential_decode: bool,
    /// Self-speculative decoding state (None = plain decode).
    spec: Option<SpecDecoder>,
    /// Degradation knob: global cap on per-slot draft windows.
    spec_k_cap: Option<usize>,
    /// Degradation knob: saved sub-branch mode while the bare branch is
    /// forced (None = not degraded).
    saved_mode: Option<SubMode>,
    /// Degradation knob: per-slot shadow-engine routing (indexed by
    /// slot id; reset on `open_batch`, cleared on `release_slot`).
    shadowed: Vec<bool>,
    /// Re-pack width of the lazily built shadow engine.
    shadow_bits: u8,
    /// Lower-bit shadow engine, built on the first shadow degrade.
    shadow_engine: Option<NativeEngine>,
    /// Draft-phase wall time since the last `take_step_phases` (ns).
    step_draft_ns: u64,
    /// Verify-phase wall time since the last `take_step_phases` (ns).
    step_verify_ns: u64,
}

impl NativeBackend {
    pub fn new(engine: NativeEngine, label: &str) -> NativeBackend {
        NativeBackend {
            engine,
            ws: EngineWs::default(),
            label: label.to_string(),
            paged: true,
            max_slots: 4,
            page_size: DEFAULT_PAGE_SIZE,
            pool_pages: 0,
            sequential_decode: false,
            spec: None,
            spec_k_cap: None,
            saved_mode: None,
            shadowed: Vec::new(),
            shadow_bits: 2,
            shadow_engine: None,
            step_draft_ns: 0,
            step_verify_ns: 0,
        }
    }

    pub fn from_checkpoint(
        path: &std::path::Path,
        mode: SubMode,
        label: &str,
    ) -> Result<NativeBackend> {
        let store = WeightStore::load(path)?;
        Ok(NativeBackend::new(NativeEngine::from_store(&store, mode)?, label))
    }

    /// Dense baseline: one full-capacity `KvCache` per slot, no paging,
    /// no prefix reuse (the pre-pool behaviour; kept for equivalence
    /// tests and the fig7 memory-budget comparison).
    pub fn with_dense(mut self) -> NativeBackend {
        self.paged = false;
        self
    }

    /// Slot-pool width (`max_batch`). The native engine decodes slots
    /// sequentially, so this bounds concurrency/occupancy accounting —
    /// with the paged pool it can exceed the old dense default of 4
    /// because short sequences no longer pin `max_seq` bytes each.
    pub fn with_max_slots(mut self, n: usize) -> NativeBackend {
        assert!(n > 0, "zero slots");
        self.max_slots = n;
        self
    }

    /// Explicit pool geometry: `page_size` positions per page and a hard
    /// budget of `n_pages` pages. With a finite budget, admissions that
    /// cannot get pages are shed gracefully (prefill returns an error
    /// and the coordinator emits a terminal `Error` event), and a slot
    /// starved mid-decode fails [`Backend::prepare_decode`] so the
    /// serving loop terminates just that request.
    pub fn with_kv_pool(mut self, page_size: usize, n_pages: usize) -> NativeBackend {
        assert!(page_size > 0 && n_pages > 0, "degenerate pool geometry");
        self.page_size = page_size;
        self.pool_pages = n_pages;
        self
    }

    /// Deprecated no-op: draft mirrors no longer have a private pool to
    /// cap. They alias the target slot's committed pages in the ONE
    /// shared pool ([`crate::engine::kv::KvPagePool::alias_kv`]) and pay
    /// only a transient copy-on-write page plus the in-flight window, so
    /// the [`NativeBackend::with_kv_pool`] budget is the whole KV
    /// budget. Mid-decode pool exhaustion still never sheds a request —
    /// the affected slot degrades to a plain (k = 0) step while its
    /// neighbors keep speculating.
    #[deprecated(
        since = "0.1.0",
        note = "draft KV shares the target pool; size it with with_kv_pool"
    )]
    pub fn with_draft_kv_pool(self, _n_pages: usize) -> NativeBackend {
        self
    }

    /// Re-pack width for the shadow-degradation engine (default 2
    /// bits). The engine itself is built lazily on the first
    /// [`Backend::set_slot_shadow`] call.
    pub fn with_shadow_bits(mut self, bits: u8) -> NativeBackend {
        assert!(bits > 0, "zero-bit shadow");
        self.shadow_bits = bits;
        self
    }

    /// Decode listed slots one engine step at a time instead of through
    /// the weight-stationary batched step — the pre-batched behaviour,
    /// kept as an A/B baseline for the fig7/microbench comparisons.
    /// Logits are bit-identical either way; only the weight traffic (and
    /// wall-clock) differs.
    pub fn with_sequential_decode(mut self) -> NativeBackend {
        self.sequential_decode = true;
        self
    }

    /// Enable self-speculative decoding: draft up to `cfg.k` tokens per
    /// slot per step on the degraded branch ([`DraftMode::NoSub`]: the
    /// target's own weights with the sub-branch skipped;
    /// [`DraftMode::Shadow`]: a lower-bit shadow re-pack), then verify
    /// every draft in ONE multi-position weight-stationary pass. Greedy
    /// output is token-identical to plain decode; sampled output is
    /// distribution-identical to plain sampled decode (rejection
    /// sampling, see [`crate::spec::accept`]); with
    /// [`SpeculativeConfig::adaptive`] each slot's window follows its
    /// acceptance-rate EWMA. Speculating slots gain a rollback-able
    /// draft KV mirror: on the (default) paged store the mirror ALIASES
    /// the target slot's committed pages in the one shared pool —
    /// refcount bumps, no copies — and privatizes only the boundary page
    /// it appends to, so drafting costs ~one transient copy-on-write
    /// page per in-flight window instead of a second KV budget; dense
    /// mirrors preallocate capacity up front like every dense cache.
    /// Mirrors fill lazily on a slot's first speculative step, so slots
    /// that only ever plain-decode pay no draft compute or pages;
    /// `open_batch` resets the mirrors, so a speculative backend drives
    /// one live batch at a time. A dense-mirrored slot must be stepped
    /// via [`Backend::decode_speculative`] for its whole lifetime;
    /// shared mirrors resync from the target each step, so paged slots
    /// may mix plain and speculative steps freely.
    pub fn with_speculative(mut self, cfg: SpeculativeConfig) -> NativeBackend {
        self.spec = Some(SpecDecoder::new(cfg, &self.engine));
        self
    }

    pub fn engine(&self) -> &NativeEngine {
        &self.engine
    }

    pub fn traffic(&self) -> &crate::engine::Traffic {
        &self.ws.traffic
    }

    /// Draft-side traffic, metered apart from the target counters in
    /// [`NativeBackend::traffic`] (None when speculation is off). The
    /// verifier's `weight_bytes` land in the target counters — charged
    /// once per step regardless of K — while every draft step charges
    /// the (cheaper) draft stream here.
    pub fn draft_traffic(&self) -> Option<&crate::engine::Traffic> {
        self.spec.as_ref().map(|s| &s.ws.traffic)
    }

    pub fn reset_traffic(&mut self) {
        self.ws.traffic.reset();
        if let Some(spec) = self.spec.as_mut() {
            spec.ws.traffic.reset();
        }
    }

    /// Deprecated: always None. Draft mirrors have no private pool any
    /// more — they alias the target's pages in the ONE shared pool, so
    /// every draft-side page event (aliases, copy-on-writes, transient
    /// window pages) lands in [`Backend::kv_stats`], which now reports
    /// the WHOLE KV budget of a speculative backend.
    #[deprecated(
        since = "0.1.0",
        note = "draft KV shares the target pool; read kv_stats (pages_aliased, cow_copies)"
    )]
    pub fn draft_kv_stats(&self) -> Option<KvPoolStats> {
        None
    }

    /// The per-slot decode loop ([`NativeBackend::with_sequential_decode`]):
    /// one full engine step — and one full pass over the weights — per
    /// occupied slot.
    fn decode_sequential(
        &mut self,
        state: &mut BatchState,
        tokens: &[SlotToken],
    ) -> Result<Vec<Vec<f32>>> {
        // same contract as the batched path: a slot may be listed once
        // (double-stepping would silently advance its KV twice); slot
        // counts are small, so the quadratic scan beats allocating a
        // bitmap sized by a caller-supplied id
        for (idx, st) in tokens.iter().enumerate() {
            if tokens[..idx].iter().any(|p| p.slot == st.slot) {
                bail!("decode: slot {} listed twice", st.slot);
            }
        }
        // validate every slot before stepping any, like the batched path:
        // a mid-loop error must not leave earlier slots silently advanced
        match state {
            BatchState::Native { slots } => {
                for st in tokens {
                    let Some(kv) = slots.get(st.slot).and_then(|s| s.as_ref()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv cache full", st.slot);
                    }
                }
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let kv = slots[st.slot].as_mut().expect("validated above");
                    let eng = if self.shadowed.get(st.slot).copied().unwrap_or(false) {
                        self.shadow_engine.as_ref().unwrap_or(&self.engine)
                    } else {
                        &self.engine
                    };
                    out.push(eng.decode_one(st.token, kv, &mut self.ws));
                }
                Ok(out)
            }
            BatchState::NativePaged { pool, slots } => {
                for st in tokens {
                    let Some(kv) = slots.get_mut(st.slot).and_then(|s| s.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv view full", st.slot);
                    }
                    // pages were reserved by prepare_decode; this is a
                    // no-op backstop for callers that skipped it
                    let pos = kv.len();
                    pool.ensure_range(kv, pos, pos + 1)
                        .with_context(|| format!("decoding slot {} at position {pos}", st.slot))?;
                }
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let kv = slots[st.slot].as_mut().expect("validated above");
                    let eng = if self.shadowed.get(st.slot).copied().unwrap_or(false) {
                        self.shadow_engine.as_ref().unwrap_or(&self.engine)
                    } else {
                        &self.engine
                    };
                    let mut bound = PagedKvRef { pool: &mut *pool, kv };
                    out.push(eng.decode_one(st.token, &mut bound, &mut self.ws));
                }
                Ok(out)
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }

    /// One weight-stationary batched step over the listed slots through
    /// either the full engine or the lower-bit shadow re-pack (the
    /// `decode` wrapper partitions by [`Backend::slot_shadowed`]; both
    /// engines share the KV geometry, so shadow steps write the same
    /// cache layout and the slot stays resumable on the full engine).
    fn decode_batched(
        &mut self,
        state: &mut BatchState,
        tokens: &[SlotToken],
        use_shadow: bool,
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        let engine = if use_shadow {
            self.shadow_engine.as_ref().context("shadow engine not built")?
        } else {
            &self.engine
        };
        match state {
            BatchState::Native { slots } => {
                // distinct slots own distinct caches: split the borrows
                let mut refs: Vec<Option<&mut KvCache>> =
                    slots.iter_mut().map(|s| s.as_mut()).collect();
                let mut batch: Vec<&mut dyn KvSlot> = Vec::with_capacity(tokens.len());
                let mut toks = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(kv) = refs.get_mut(st.slot).and_then(|r| r.take()) else {
                        bail!("decode: slot {} is not occupied (or listed twice)", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv cache full", st.slot);
                    }
                    toks.push(st.token);
                    batch.push(kv as &mut dyn KvSlot);
                }
                let mut sb = SlotBatch { slots: batch };
                Ok(engine.step_batch(&toks, &mut sb, &mut self.ws))
            }
            BatchState::NativePaged { pool, slots } => {
                // pages were reserved by prepare_decode; this is a no-op
                // backstop for callers that skipped it
                for st in tokens {
                    let Some(kv) = slots.get_mut(st.slot).and_then(|s| s.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv view full", st.slot);
                    }
                    let pos = kv.len();
                    pool.ensure_range(kv, pos, pos + 1)
                        .with_context(|| format!("decoding slot {} at position {pos}", st.slot))?;
                }
                let mut refs: Vec<Option<&mut PagedKv>> =
                    slots.iter_mut().map(|s| s.as_mut()).collect();
                let mut sel: Vec<&mut PagedKv> = Vec::with_capacity(tokens.len());
                let mut toks = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(kv) = refs.get_mut(st.slot).and_then(|r| r.take()) else {
                        bail!("decode: slot {} listed twice", st.slot);
                    };
                    toks.push(st.token);
                    sel.push(kv);
                }
                let mut sb = PagedSlotBatch { pool, slots: sel };
                Ok(engine.step_batch(&toks, &mut sb, &mut self.ws))
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }

    /// Register an admission with the speculative state: an empty draft
    /// mirror, plus — for dense mirrors only — the prompt queued in the
    /// slot's lazy catch-up list (the dense draft attends over its own
    /// representations, so the prompt is mirrored by the slot's FIRST
    /// draft pass — and never, if the slot never speculates). Shared
    /// mirrors queue nothing: each speculative step aliases the slot's
    /// committed page table directly, so there is no catch-up re-prefill
    /// to schedule.
    fn draft_admit(&mut self, slot: usize, prompt: &[u32]) -> Result<()> {
        let spec = self.spec.as_mut().expect("draft_admit without speculative config");
        spec.kv.occupy(&self.engine.cfg, slot)?;
        let p = spec
            .pending
            .get_mut(slot)
            .with_context(|| format!("draft admit: slot {slot} out of range"))?;
        p.clear();
        if matches!(spec.kv, DraftKv::Dense { .. }) {
            p.extend_from_slice(prompt);
        }
        // a fresh request starts its adaptive window optimistic
        if let Some(c) = spec.ctrl.get_mut(slot) {
            *c = KController::new(spec.cfg.k);
        }
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn cfg(&self) -> &Config {
        &self.engine.cfg
    }

    fn max_batch(&self) -> usize {
        self.max_slots
    }

    fn continuous(&self) -> bool {
        // every slot owns an independent KV view: admit any time.
        true
    }

    fn open_batch(&mut self, capacity: usize) -> Result<BatchState> {
        if capacity == 0 {
            bail!("zero-capacity batch");
        }
        self.shadowed.clear();
        self.shadowed.resize(capacity, false);
        let cfg = &self.engine.cfg;
        let pages_per_seq = (cfg.max_seq + self.page_size - 1) / self.page_size;
        let n_pages = if self.pool_pages > 0 { self.pool_pages } else { capacity * pages_per_seq };
        // opening a batch resets the draft mirrors (one live batch per
        // speculative backend). On the paged store the mirrors own no
        // pool of their own — they alias the target pool's pages
        // per-step, so the `n_pages` budget below is the backend's whole
        // KV memory.
        if let Some(spec) = self.spec.as_mut() {
            if self.paged {
                spec.kv.open_shared(capacity);
            } else {
                spec.kv.open_dense(capacity);
            }
            spec.pending = (0..capacity).map(|_| Vec::new()).collect();
            spec.ctrl = (0..capacity).map(|_| KController::new(spec.cfg.k)).collect();
        }
        if !self.paged {
            return Ok(BatchState::Native { slots: (0..capacity).map(|_| None).collect() });
        }
        let pool = KvPagePool::new(KvPoolConfig::new(
            cfg.n_layers,
            cfg.n_heads,
            cfg.head_dim(),
            self.page_size,
            n_pages,
        ));
        Ok(BatchState::NativePaged { pool, slots: (0..capacity).map(|_| None).collect() })
    }

    /// Admit one prompt — a group of one through the same
    /// weight-stationary multi-position pass as [`Backend::prefill_slots`],
    /// so even a lone continuous-mode admission streams the quantized
    /// weights once per transformer layer instead of once per prompt
    /// position.
    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>> {
        let mut out = self.prefill_slots(state, &[(slot, prompt)])?;
        Ok(out.remove(0))
    }

    /// **Batched prefill**: the whole admission group flows through ONE
    /// multi-position weight-stationary pass
    /// ([`NativeEngine::step_batch_multi`]), so quantized weights stream
    /// once per transformer layer for the group instead of once per
    /// prompt position — per position-row the float operations (and so
    /// the logits) are bit-identical to sequential per-position prefill.
    /// Prompts need not be length-aligned — the native engine has no
    /// lock-step restriction.
    fn prefill_slots(
        &mut self,
        state: &mut BatchState,
        admissions: &[(usize, &[u32])],
    ) -> Result<Vec<Vec<f32>>> {
        if admissions.is_empty() {
            return Ok(Vec::new());
        }
        for (idx, &(slot, prompt)) in admissions.iter().enumerate() {
            if prompt.is_empty() {
                bail!("empty prompt");
            }
            if admissions[..idx].iter().any(|&(s, _)| s == slot) {
                bail!("slot {slot} admitted twice");
            }
        }
        let logits: Vec<Vec<f32>> = match state {
            BatchState::Native { slots } => {
                for &(slot, _) in admissions {
                    if slot >= slots.len() {
                        bail!("slot {slot} out of range ({} slots)", slots.len());
                    }
                    if slots[slot].is_some() {
                        bail!("slot {slot} is already occupied");
                    }
                }
                let cfg = &self.engine.cfg;
                let mut caches: Vec<KvCache> = admissions
                    .iter()
                    .map(|_| KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim()))
                    .collect();
                let groups: Vec<&[u32]> = admissions.iter().map(|&(_, p)| p).collect();
                let flat = {
                    let batch: Vec<&mut dyn KvSlot> =
                        caches.iter_mut().map(|c| c as &mut dyn KvSlot).collect();
                    let mut sb = SlotBatch { slots: batch };
                    self.engine.step_batch_multi(&groups, &mut sb, &mut self.ws, false)
                };
                for (&(slot, _), kv) in admissions.iter().zip(caches) {
                    slots[slot] = Some(kv);
                }
                flat.into_iter().map(|mut per| per.pop().expect("one logits row")).collect()
            }
            BatchState::NativePaged { pool, slots } => {
                for &(slot, _) in admissions {
                    if slot >= slots.len() {
                        bail!("slot {slot} out of range ({} slots)", slots.len());
                    }
                    if slots[slot].is_some() {
                        bail!("slot {slot} is already occupied");
                    }
                }
                // map prefixes + make every prompt writable BEFORE the
                // engine runs: exhaustion sheds the whole group here with
                // no engine state touched
                let mut kvs: Vec<PagedKv> = Vec::with_capacity(admissions.len());
                let mut reused: Vec<usize> = Vec::with_capacity(admissions.len());
                for &(_, prompt) in admissions {
                    let mut kv = pool.new_kv(self.engine.cfg.max_seq);
                    let r = pool.adopt_prefix(&mut kv, prompt);
                    if let Err(e) = pool.ensure_range(&mut kv, r, prompt.len()) {
                        pool.release_kv(&mut kv);
                        for mut k in kvs {
                            pool.release_kv(&mut k);
                        }
                        return Err(e).with_context(|| {
                            format!(
                                "admitting a {}-token prompt in a group of {}",
                                prompt.len(),
                                admissions.len()
                            )
                        });
                    }
                    kvs.push(kv);
                    reused.push(r);
                }
                for &r in &reused {
                    pool.record_reuse(r);
                }
                let groups: Vec<&[u32]> =
                    admissions.iter().zip(&reused).map(|(&(_, p), &r)| &p[r..]).collect();
                let flat = {
                    let sel: Vec<&mut PagedKv> = kvs.iter_mut().collect();
                    let mut sb = PagedSlotBatch { pool, slots: sel };
                    self.engine.step_batch_multi(&groups, &mut sb, &mut self.ws, false)
                };
                for (&(slot, prompt), kv) in admissions.iter().zip(kvs) {
                    pool.register_prefix(&kv, prompt);
                    slots[slot] = Some(kv);
                }
                flat.into_iter().map(|mut per| per.pop().expect("one logits row")).collect()
            }
            _ => bail!("native backend got a foreign batch state"),
        };
        if self.spec.is_some() {
            for &(slot, prompt) in admissions {
                if let Err(e) = self.draft_admit(slot, prompt) {
                    // aligned-group admission fails as a unit: unwind the
                    // slots already placed so target and draft agree
                    for &(s, _) in admissions {
                        let _ = self.release_slot(state, s);
                    }
                    return Err(e).context("draft admission");
                }
            }
        }
        Ok(logits)
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        let out = if self.sequential_decode {
            self.decode_sequential(state, tokens)?
        } else if tokens.iter().any(|st| self.slot_shadowed(st.slot)) {
            // split shadow-routed slots from full-engine slots, step each
            // group through its engine, reassemble in input order
            let mut norm: Vec<SlotToken> = Vec::new();
            let mut nidx: Vec<usize> = Vec::new();
            let mut shad: Vec<SlotToken> = Vec::new();
            let mut sidx: Vec<usize> = Vec::new();
            for (i, st) in tokens.iter().enumerate() {
                if self.slot_shadowed(st.slot) {
                    shad.push(*st);
                    sidx.push(i);
                } else {
                    norm.push(*st);
                    nidx.push(i);
                }
            }
            let mut merged: Vec<Option<Vec<f32>>> = vec![None; tokens.len()];
            for (i, row) in nidx.into_iter().zip(self.decode_batched(state, &norm, false)?) {
                merged[i] = Some(row);
            }
            for (i, row) in sidx.into_iter().zip(self.decode_batched(state, &shad, true)?) {
                merged[i] = Some(row);
            }
            merged.into_iter().map(|r| r.expect("every listed slot decoded")).collect()
        } else {
            self.decode_batched(state, tokens, false)?
        };
        // plain-decoded tokens of DENSE speculative mirrors queue in the
        // lazy catch-up list, so a slot degraded to plain decode (shadow
        // routing, K capped to 0) can return to speculative stepping
        // with `draft len + pending == target len` intact. Shared
        // mirrors queue nothing: the next speculative step re-aliases
        // the target's committed page table, so plain and speculative
        // steps mix freely on the paged store.
        if let Some(spec) = self.spec.as_mut() {
            if matches!(spec.kv, DraftKv::Dense { .. }) {
                for st in tokens {
                    if spec.kv.len(st.slot).is_none() {
                        continue;
                    }
                    if let Some(p) = spec.pending.get_mut(st.slot) {
                        p.push(st.token);
                    }
                }
            }
        }
        Ok(out)
    }

    fn prepare_decode(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        let BatchState::NativePaged { pool, slots } = state else {
            return Ok(()); // dense caches are preallocated
        };
        let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
            bail!("prepare_decode: slot {slot} is not occupied");
        };
        if kv.remaining() == 0 {
            bail!("slot {slot}: kv view full");
        }
        let pos = kv.len();
        pool.ensure_range(kv, pos, pos + 1)
            .with_context(|| format!("slot {slot} cannot advance past position {pos}"))
    }

    fn speculative(&self) -> Option<SpeculativeConfig> {
        self.spec.as_ref().map(|s| s.cfg)
    }

    /// One self-speculative step over the listed slots: batched drafting
    /// on the degraded branch (argmax chains for greedy slots, draws
    /// from the draft's post-params distribution for sampled slots), ONE
    /// multi-position verify pass over the target
    /// ([`NativeEngine::step_batch_multi_sel`] — verifier weights stream
    /// once per step regardless of K, greedy slots fetch only argmax
    /// ids, sampled slots fetch the full rows they need), per-mode
    /// acceptance (argmax match vs rejection sampling with residual
    /// resampling), and KV rollback of every rejected position on both
    /// caches. Near `max_seq` the draft window clamps; under pool
    /// pressure a slot degrades to a plain (k = 0) verify step instead
    /// of erroring; with [`SpeculativeConfig::adaptive`] each slot's
    /// window follows its acceptance-rate EWMA.
    fn decode_speculative(
        &mut self,
        state: &mut BatchState,
        reqs: &[SpecSlot],
    ) -> Result<Vec<SpecStep>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(spec_cfg) = self.spec.as_ref().map(|s| s.cfg) else {
            bail!("speculative decoding is not configured on this backend");
        };
        for (idx, st) in reqs.iter().enumerate() {
            if reqs[..idx].iter().any(|p| p.slot == st.slot) {
                bail!("decode: slot {} listed twice", st.slot);
            }
        }
        let max_seq = self.engine.cfg.max_seq;
        let n = reqs.len();

        // Phase 0: pick each slot's draft window — the adaptive
        // controller's when enabled, else the configured K — then
        // validate slots, clamp the window to the space left before
        // max_seq, and reserve the verify rows' pages.
        let mut base_k: Vec<usize> = Vec::with_capacity(n);
        if spec_cfg.adaptive {
            let spec = self.spec.as_mut().expect("config checked above");
            for st in reqs {
                let c = spec
                    .ctrl
                    .get_mut(st.slot)
                    .with_context(|| format!("decode: slot {} out of range", st.slot))?;
                base_k.push(c.next_k());
            }
        } else {
            base_k.resize(n, spec_cfg.k);
        }
        if let Some(cap) = self.spec_k_cap {
            // load-adaptive degradation: every slot's window is capped
            // this step; cap 0 degrades to plain verify steps without
            // touching the mirrors, so lifting the cap resumes drafting
            for k in &mut base_k {
                *k = (*k).min(cap);
            }
        }
        let mut lens: Vec<usize> = Vec::with_capacity(n);
        let mut ks: Vec<usize> = Vec::with_capacity(n);
        match state {
            BatchState::Native { slots } => {
                for (i, st) in reqs.iter().enumerate() {
                    let Some(kv) = slots.get(st.slot).and_then(|s| s.as_ref()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv cache full", st.slot);
                    }
                    lens.push(kv.len);
                    ks.push(base_k[i].min(max_seq - kv.len - 1));
                }
            }
            BatchState::NativePaged { pool, slots } => {
                for (i, st) in reqs.iter().enumerate() {
                    let Some(kv) = slots.get_mut(st.slot).and_then(|s| s.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv view full", st.slot);
                    }
                    let len = kv.len();
                    let mut k = base_k[i].min(max_seq - len - 1);
                    if k > 0 && pool.ensure_range(kv, len, len + 1 + k).is_err() {
                        k = 0; // pool pressure: degrade to a plain step
                    }
                    pool.ensure_range(kv, len, len + 1)
                        .with_context(|| format!("decoding slot {} at position {len}", st.slot))?;
                    lens.push(len);
                    ks.push(k);
                }
            }
            _ => bail!("native backend got a foreign batch state"),
        }

        // Phase 0b: bring each draft mirror to the target's committed
        // state. Shared mirrors sync by aliasing the target slot's page
        // table — refcount bumps out of the ONE shared pool, no copies —
        // then reserve their k-token window, which copy-on-writes the
        // partially filled boundary page so the verify rows (written to
        // the target's own copy later this step) never land in a shared
        // page. Dense mirrors (plus their lazy catch-up queue) must sit
        // exactly at the target's length, and a drafting slot needs
        // `pending + k_i` mirror positions (the queued catch-up tokens
        // ride the first draft pass).
        match state {
            BatchState::NativePaged { pool, slots } => {
                let spec = self.spec.as_mut().expect("config checked above");
                for (i, st) in reqs.iter().enumerate() {
                    if spec.kv.len(st.slot).is_none() {
                        bail!(
                            "slot {}: no draft kv mirror (admitted without speculation?)",
                            st.slot
                        );
                    }
                    if ks[i] == 0 {
                        continue; // degraded slots touch no draft pages
                    }
                    let target = slots[st.slot].as_ref().expect("validated in phase 0");
                    spec.kv.sync_to_target(pool, st.slot, target);
                    if spec.kv.ensure(st.slot, ks[i], Some(&mut *pool)).is_err() {
                        // shared-pool pressure: degrade to a plain verify
                        // step, returning any partially mapped window —
                        // including a still-shared boundary alias, which
                        // the verify write must own exclusively
                        spec.kv.retain_target_prefix(pool, st.slot, target);
                        ks[i] = 0;
                    }
                }
            }
            BatchState::Native { .. } => {
                let spec = self.spec.as_mut().expect("config checked above");
                for (i, st) in reqs.iter().enumerate() {
                    let Some(dlen) = spec.kv.len(st.slot) else {
                        bail!(
                            "slot {}: no draft kv mirror (admitted without speculation?)",
                            st.slot
                        );
                    };
                    let lag = spec.pending.get(st.slot).map_or(0, |p| p.len());
                    if dlen + lag != lens[i] {
                        bail!(
                            "slot {}: draft kv at {dlen} (+{lag} pending) but target at {} \
                             (mixed decode/decode_speculative on one slot?)",
                            st.slot,
                            lens[i]
                        );
                    }
                    // degraded (k = 0) slots write nothing to the mirror —
                    // their committed tokens queue in `pending` instead
                    if ks[i] > 0 && spec.kv.ensure(st.slot, lag + ks[i], None).is_err() {
                        ks[i] = 0; // draft capacity pressure: degrade, not error
                    }
                }
            }
            _ => unreachable!("state variant validated in phase 0"),
        }

        // Phase 1: batched drafting on the degraded branch — argmax
        // chains for greedy slots, q-distribution draws for sampled ones
        // (q recorded per position for the accept ratio). For NoSub the
        // draft engine IS the target with its sub-branch switched off
        // for the duration of the draft steps.
        let samplings: Vec<Option<&SamplingParams>> = reqs
            .iter()
            .map(|r| if r.sampling.is_sampled() { Some(&r.sampling) } else { None })
            .collect();
        let draft_t0 = std::time::Instant::now();
        let (drafts, qs): (Vec<Vec<u32>>, Vec<Vec<Vec<f64>>>) = {
            let saved = self.engine.mode;
            if matches!(spec_cfg.draft, DraftMode::NoSub) {
                self.engine.mode = SubMode::None;
            }
            let spec = self.spec.as_mut().expect("config checked above");
            let SpecDecoder { shadow, ws, kv, pending, rng, .. } = spec;
            let draft_engine: &NativeEngine = match shadow {
                Some(e) => e,
                None => &self.engine,
            };
            let slot_ids: Vec<usize> = reqs.iter().map(|t| t.slot).collect();
            let cur0: Vec<u32> = reqs.iter().map(|t| t.token).collect();
            let pool = match state {
                BatchState::NativePaged { pool, .. } => Some(&mut *pool),
                _ => None,
            };
            let out = draft_tokens(
                draft_engine,
                kv,
                ws,
                &slot_ids,
                pending,
                &cur0,
                &ks,
                &samplings,
                rng,
                pool,
            );
            self.engine.mode = saved;
            out
        };
        let draft_ns = draft_t0.elapsed().as_nanos() as u64;
        self.step_draft_ns += draft_ns;
        if crate::trace::request_on() {
            let end = crate::trace::now_ns();
            crate::trace::span_closed(
                crate::trace::Phase::Draft,
                0,
                crate::trace::SLOT_NONE,
                end.saturating_sub(draft_ns),
                end,
                ks.iter().sum::<usize>() as u64,
            );
        }

        // Phase 2: verify — every slot's input token plus all its drafts
        // in ONE multi-position weight-stationary pass over the target.
        // Greedy slots only need the argmax id per row (no rows × vocab
        // logits materialized); sampled slots need the full rows to form
        // the target distributions.
        let groups_store: Vec<Vec<u32>> = reqs
            .iter()
            .zip(&drafts)
            .map(|(st, d)| {
                let mut g = Vec::with_capacity(1 + d.len());
                g.push(st.token);
                g.extend_from_slice(d);
                g
            })
            .collect();
        let groups: Vec<&[u32]> = groups_store.iter().map(|g| g.as_slice()).collect();
        let slot_ids: Vec<usize> = reqs.iter().map(|t| t.slot).collect();
        let want: Vec<RowsWant> = samplings
            .iter()
            .map(|s| if s.is_some() { RowsWant::All } else { RowsWant::Argmax })
            .collect();
        let verify_t0 = std::time::Instant::now();
        let verify: Vec<SlotLogits> = match state {
            BatchState::Native { slots } => {
                let mut sb = SlotBatch::select(slots, &slot_ids);
                self.engine.step_batch_multi_sel(&groups, &mut sb, &mut self.ws, &want)
            }
            BatchState::NativePaged { pool, slots } => {
                let mut sb = PagedSlotBatch::select(pool, slots, &slot_ids);
                self.engine.step_batch_multi_sel(&groups, &mut sb, &mut self.ws, &want)
            }
            _ => unreachable!("state variant validated in phase 0"),
        };
        let verify_ns = verify_t0.elapsed().as_nanos() as u64;
        self.step_verify_ns += verify_ns;
        if crate::trace::request_on() {
            let end = crate::trace::now_ns();
            crate::trace::span_closed(
                crate::trace::Phase::Verify,
                0,
                crate::trace::SLOT_NONE,
                end.saturating_sub(verify_ns),
                end,
                groups.iter().map(|g| g.len()).sum::<usize>() as u64,
            );
        }

        // Phase 3: per-mode acceptance, then rollback of every rejected
        // position. The target truncates; a shared mirror retains only
        // the aliases still matching the committed prefix — acceptance
        // and rejection are the same operation, and the diverged
        // copy-on-write boundary plus the draft window return to the
        // pool; a dense mirror truncates, or on full acceptance queues
        // the last committed token in its lazy catch-up list (the
        // mirror never fed it, so it rides the NEXT step's first draft
        // pass with no extra weight stream).
        let mut out: Vec<SpecStep> = Vec::with_capacity(n);
        for (i, st) in reqs.iter().enumerate() {
            let spec = self.spec.as_mut().expect("config checked above");
            let (a, next) = match &verify[i] {
                SlotLogits::Argmax(ids) => greedy_accept_ids(&drafts[i], ids),
                SlotLogits::Rows(rows) => {
                    let params = samplings[i].expect("full rows only fetched for sampled slots");
                    // target rows build lazily: rows past the first
                    // rejection never pay the distribution() sort
                    stochastic_accept_with(
                        &drafts[i],
                        &qs[i],
                        |j| distribution(&rows[j], params),
                        &mut spec.rng,
                    )
                }
            };
            if spec_cfg.adaptive {
                spec.ctrl[st.slot].observe(ks[i], a);
            }
            let committed = lens[i] + 1 + a;
            match state {
                BatchState::Native { slots } => {
                    slots[st.slot].as_mut().expect("validated above").truncate(committed);
                    if a == ks[i] {
                        let last = if ks[i] == 0 { st.token } else { drafts[i][ks[i] - 1] };
                        spec.pending[st.slot].push(last);
                    } else {
                        // the drafting pass drained this slot's pending
                        // queue, so the mirror holds exactly the
                        // committed prefix after the truncate
                        spec.kv.truncate(st.slot, committed);
                    }
                }
                BatchState::NativePaged { pool, slots } => {
                    let kv = slots[st.slot].as_mut().expect("validated above");
                    pool.truncate_kv(kv, committed);
                    spec.kv.retain_target_prefix(pool, st.slot, kv);
                }
                _ => unreachable!("state variant validated in phase 0"),
            }
            out.push(SpecStep { accepted: drafts[i][..a].to_vec(), next, proposed: ks[i] });
        }
        Ok(out)
    }

    fn weight_bytes(&self) -> Option<u64> {
        let draft = self.spec.as_ref().map_or(0, |s| s.ws.traffic.weight_bytes);
        Some(self.ws.traffic.weight_bytes + draft)
    }

    fn take_step_phases(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.step_draft_ns), std::mem::take(&mut self.step_verify_ns))
    }

    fn preemptible(&self) -> bool {
        true
    }

    /// Swap `slot` out into a host-side parking buffer: a bit-exact copy
    /// of the committed target KV, the dense draft mirror (when the slot
    /// speculates), the mirror's lazy catch-up queue, and the adaptive-K
    /// controller. A SHARED draft mirror parks as nothing at all:
    /// between steps it is a pure function of the target's committed
    /// pages, so parking just releases its aliases — the shared pages
    /// serialize once, with the target — and the next speculative step
    /// after restore re-aliases them bit-identically. The slot is freed
    /// — on the paged store its pages return to the pool, which is the
    /// memory another admission needs.
    fn swap_out(&mut self, state: &mut BatchState, slot: usize) -> Result<ParkedSlot> {
        let target = match state {
            BatchState::Native { slots } => {
                let kv = slots
                    .get_mut(slot)
                    .and_then(|s| s.take())
                    .with_context(|| format!("swap out: slot {slot} is not occupied"))?;
                kv.park()
            }
            BatchState::NativePaged { pool, slots } => {
                let mut kv = slots
                    .get_mut(slot)
                    .and_then(|s| s.take())
                    .with_context(|| format!("swap out: slot {slot} is not occupied"))?;
                pool.park_kv(&mut kv)
            }
            _ => bail!("native backend got a foreign batch state"),
        };
        let (draft, pending, ctrl) = match self.spec.as_mut() {
            Some(spec) => {
                let draft = match state {
                    BatchState::NativePaged { pool, .. } => spec.kv.park(slot, Some(pool)),
                    _ => spec.kv.park(slot, None),
                };
                let pending = spec.pending.get_mut(slot).map(std::mem::take).unwrap_or_default();
                let ctrl = spec.ctrl.get(slot).cloned();
                if let Some(c) = spec.ctrl.get_mut(slot) {
                    *c = KController::new(spec.cfg.k);
                }
                (draft, pending, ctrl)
            }
            None => (None, Vec::new(), None),
        };
        // shadow routing is a property of the live slot, not the request
        if let Some(s) = self.shadowed.get_mut(slot) {
            *s = false;
        }
        Ok(ParkedSlot { target, draft, pending, ctrl })
    }

    /// Restore a parked slot into the free slot `slot`: target KV first,
    /// then the draft mirror, catch-up queue and controller, so a
    /// subsequent (greedy) decode is bit-identical to a run that was
    /// never preempted. A mid-restore failure unwinds the target so the
    /// surface is unchanged and `parked` stays valid for a later retry.
    fn swap_in(&mut self, state: &mut BatchState, slot: usize, parked: &ParkedSlot)
        -> Result<()> {
        match state {
            BatchState::Native { slots } => {
                if slot >= slots.len() {
                    bail!("swap in: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("swap in: slot {slot} is already occupied");
                }
                let cfg = &self.engine.cfg;
                let mut kv = KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim());
                kv.unpark(&parked.target);
                slots[slot] = Some(kv);
            }
            BatchState::NativePaged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("swap in: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("swap in: slot {slot} is already occupied");
                }
                let kv = pool
                    .unpark_kv(&parked.target, self.engine.cfg.max_seq)
                    .context("swap in: target kv")?;
                slots[slot] = Some(kv);
            }
            _ => bail!("native backend got a foreign batch state"),
        }
        if let Some(spec) = self.spec.as_mut() {
            let restored = match parked.draft.as_ref() {
                Some(d) => spec.kv.unpark(&self.engine.cfg, slot, d),
                // shared mirrors always park as None (re-derived by
                // re-aliasing the restored target), as do slots parked
                // by a then-non-speculative backend: resume with an
                // empty mirror
                None => spec.kv.occupy(&self.engine.cfg, slot),
            };
            if let Err(e) = restored {
                match state {
                    BatchState::Native { slots } => slots[slot] = None,
                    BatchState::NativePaged { pool, slots } => {
                        if let Some(mut kv) = slots[slot].take() {
                            pool.release_kv(&mut kv);
                        }
                    }
                    _ => unreachable!("state variant validated above"),
                }
                return Err(e).context("swap in: draft kv mirror");
            }
            let p = spec.pending.get_mut(slot).expect("mirror restored into this slot");
            p.clear();
            p.extend_from_slice(&parked.pending);
            if let Some(c) = spec.ctrl.get_mut(slot) {
                *c = parked.ctrl.clone().unwrap_or_else(|| KController::new(spec.cfg.k));
            }
        }
        Ok(())
    }

    fn set_spec_k_cap(&mut self, cap: Option<usize>) {
        self.spec_k_cap = cap;
    }

    fn set_bare_branch(&mut self, bare: bool) {
        if bare {
            if self.saved_mode.is_none() {
                self.saved_mode = Some(self.engine.mode);
                self.engine.mode = SubMode::None;
            }
        } else if let Some(m) = self.saved_mode.take() {
            self.engine.mode = m;
        }
    }

    fn set_slot_shadow(&mut self, slot: usize, on: bool) -> Result<()> {
        if slot >= self.shadowed.len() {
            bail!("shadow: slot {slot} out of range ({} slots)", self.shadowed.len());
        }
        if on && self.shadow_engine.is_none() {
            self.shadow_engine = Some(self.engine.shadow(self.shadow_bits));
        }
        self.shadowed[slot] = on;
        Ok(())
    }

    fn slot_shadowed(&self, slot: usize) -> bool {
        self.shadowed.get(slot).copied().unwrap_or(false)
    }

    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        match state {
            BatchState::Native { slots } => {
                if slot >= slots.len() {
                    bail!("release: slot {slot} out of range ({} slots)", slots.len());
                }
                slots[slot] = None;
            }
            BatchState::NativePaged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("release: slot {slot} out of range ({} slots)", slots.len());
                }
                if let Some(mut kv) = slots[slot].take() {
                    // pages shared with the prefix cache (or siblings)
                    // stay resident; private pages return to the free list
                    pool.release_kv(&mut kv);
                }
            }
            _ => bail!("native backend got a foreign batch state"),
        }
        if let Some(spec) = self.spec.as_mut() {
            match state {
                BatchState::NativePaged { pool, .. } => spec.kv.release(slot, Some(pool)),
                _ => spec.kv.release(slot, None),
            }
            if let Some(p) = spec.pending.get_mut(slot) {
                p.clear();
            }
            if let Some(c) = spec.ctrl.get_mut(slot) {
                *c = KController::new(spec.cfg.k);
            }
        }
        if let Some(s) = self.shadowed.get_mut(slot) {
            *s = false;
        }
        Ok(())
    }

    fn kv_stats(&self, state: &BatchState) -> Option<KvPoolStats> {
        match state {
            BatchState::NativePaged { pool, .. } => Some(pool.stats()),
            _ => None,
        }
    }

    fn name(&self) -> String {
        format!("native:{}", self.label)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

struct PjrtArtifacts {
    /// prefill execs by (batch, t_step), t_steps descending
    prefill: Vec<(usize, usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
    /// decode execs by batch
    decode: Vec<(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
}

pub struct PjrtBackend {
    cfg: Config,
    label: String,
    arts: PjrtArtifacts,
    batches: Vec<usize>,
    kv_numel: usize,
    kv_shape: Vec<usize>,
    per_lane: bool,
}

impl PjrtBackend {
    /// Load + compile the serve artifacts for `(model, checkpoint)`.
    pub fn new(registry: &mut ExecRegistry, store: &WeightStore,
               batches: &[usize], label: &str) -> Result<PjrtBackend> {
        let cfg = store.cfg.clone();
        let quantized = store.is_quantized();
        let model = cfg.name.clone();
        let mut prefill = Vec::new();
        let mut decode = Vec::new();
        for &b in batches {
            for t_step in [128usize, 32] {
                let name = format!(
                    "prefill_{model}_{}_b{b}_t{t_step}",
                    if quantized { "q" } else { "fp" }
                );
                let exec = registry.load(&name)?;
                let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
                prefill.push((b, t_step, exec, feed));
            }
            let name = Manifest::step_name("decode", &model, quantized, b);
            let exec = registry.load(&name)?;
            let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
            decode.push((b, exec, feed));
        }
        // kv shape from the b=smallest decode spec, scaled per batch at use
        let kv_spec = decode[0]
            .1
            .spec
            .inputs
            .iter()
            .find(|t| t.name == "kv_k")
            .context("decode artifact missing kv_k input")?
            .clone();
        Ok(PjrtBackend {
            cfg,
            label: label.to_string(),
            arts: PjrtArtifacts { prefill, decode },
            batches: batches.to_vec(),
            kv_numel: kv_spec.numel(),
            kv_shape: kv_spec.shape,
            per_lane: false,
        })
    }

    /// Per-lane mode: every slot becomes an independent batch-1 surface
    /// with its own position counter, enabling continuous (mid-flight)
    /// admission at the cost of lane-sequential execution. Requires
    /// batch-1 artifacts.
    pub fn with_per_lane(mut self, on: bool) -> PjrtBackend {
        self.per_lane = on;
        self
    }

    fn kv_len_for(&self, capacity: usize) -> usize {
        // kv shape [L, B, Tm, H, hd] recorded for the smallest batch
        let base_b = self.kv_shape[1];
        self.kv_numel / base_b * capacity
    }

    fn decode_exec(
        &self,
        capacity: usize,
    ) -> Result<&(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)> {
        self.arts
            .decode
            .iter()
            .find(|(b, _, _)| *b == capacity)
            .with_context(|| format!("no decode artifact for batch {capacity}"))
    }

    /// Run the chunked prefill (128s, then 32s, then single decode steps)
    /// over a `capacity`-lane surface; every lane consumes one of the
    /// equal-length `lane_prompts` this call. Returns the last-chunk
    /// logits, flat `[capacity * vocab]`.
    fn chunked_prefill(&self, lane_prompts: &[&[u32]], capacity: usize,
                       kv_k: &mut Vec<f32>, kv_v: &mut Vec<f32>, pos: &mut usize)
                       -> Result<Vec<f32>> {
        if lane_prompts.len() != capacity {
            bail!("chunked_prefill: {} lane prompts for {capacity} lanes", lane_prompts.len());
        }
        let plen = lane_prompts[0].len();
        if lane_prompts.iter().any(|p| p.len() != plen) {
            bail!("chunked_prefill: lane prompts are not length-aligned");
        }
        let mut consumed = 0usize;
        let mut last_logits: Vec<f32> = Vec::new();
        while consumed < plen {
            let rem = plen - consumed;
            let chunk = self
                .arts
                .prefill
                .iter()
                .filter(|(b, t, _, _)| *b == capacity && *t <= rem)
                .map(|(_, t, _, _)| *t)
                .max();
            let (exec, feed, step) = match chunk {
                Some(t) => {
                    let (_, _, e, f) = self
                        .arts
                        .prefill
                        .iter()
                        .find(|(b, tt, _, _)| *b == capacity && *tt == t)
                        .unwrap();
                    (Arc::clone(e), Arc::clone(f), t)
                }
                None => {
                    // remainder smaller than any compiled chunk: fall back
                    // to single-step prefill through the decode artifact
                    let (_, e, f) = self.decode_exec(capacity)?;
                    (Arc::clone(e), Arc::clone(f), 1)
                }
            };
            // tokens [capacity, step]
            let mut toks = Vec::with_capacity(capacity * step);
            for prompt in lane_prompts {
                toks.extend(prompt[consumed..consumed + step].iter().map(|&t| t as i32));
            }
            let data = vec![
                Value::I32(toks),
                Value::I32(vec![*pos as i32]),
                Value::F32(std::mem::take(kv_k)),
                Value::F32(std::mem::take(kv_v)),
            ];
            let out = exec.run(&data, &feed)?;
            last_logits = out[0].as_f32()?.to_vec();
            *kv_k = match &out[1] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_k output not f32"),
            };
            *kv_v = match &out[2] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_v output not f32"),
            };
            *pos += step;
            consumed += step;
        }
        Ok(last_logits)
    }
}

impl Backend for PjrtBackend {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        *self.batches.iter().max().unwrap_or(&1)
    }

    fn continuous(&self) -> bool {
        self.per_lane
    }

    fn open_batch(&mut self, capacity: usize) -> Result<BatchState> {
        if capacity == 0 {
            bail!("zero-capacity batch");
        }
        if self.per_lane {
            if !self.batches.contains(&1) {
                bail!("per-lane pjrt serving requires batch-1 artifacts");
            }
            if capacity > self.max_batch() {
                bail!("capacity {capacity} exceeds compiled max batch {}", self.max_batch());
            }
            Ok(BatchState::PjrtLanes { lanes: (0..capacity).map(|_| None).collect() })
        } else {
            if !self.batches.contains(&capacity) {
                bail!("no compiled artifacts for batch {capacity}");
            }
            Ok(BatchState::Pjrt {
                kv_k: vec![0f32; self.kv_len_for(capacity)],
                kv_v: vec![0f32; self.kv_len_for(capacity)],
                pos: 0,
                capacity,
                occupied: vec![false; capacity],
                decoded: false,
            })
        }
    }

    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        match state {
            BatchState::PjrtLanes { lanes } => {
                if slot >= lanes.len() {
                    bail!("slot {slot} out of range ({} lanes)", lanes.len());
                }
                if lanes[slot].is_some() {
                    bail!("slot {slot} is already occupied");
                }
                let mut lane = PjrtLane {
                    kv_k: vec![0f32; self.kv_len_for(1)],
                    kv_v: vec![0f32; self.kv_len_for(1)],
                    pos: 0,
                };
                let logits = self.chunked_prefill(
                    &[prompt], 1, &mut lane.kv_k, &mut lane.kv_v, &mut lane.pos,
                )?;
                lanes[slot] = Some(lane);
                Ok(logits)
            }
            BatchState::Pjrt { .. } => {
                let mut out = self.prefill_slots(state, &[(slot, prompt)])?;
                Ok(out.remove(0))
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn prefill_slots(
        &mut self,
        state: &mut BatchState,
        admissions: &[(usize, &[u32])],
    ) -> Result<Vec<Vec<f32>>> {
        if admissions.is_empty() {
            return Ok(Vec::new());
        }
        match state {
            // per-lane surfaces are independent: admit one by one
            BatchState::PjrtLanes { .. } => {
                let mut out = Vec::with_capacity(admissions.len());
                for &(slot, prompt) in admissions {
                    out.push(self.prefill_slot(state, slot, prompt)?);
                }
                Ok(out)
            }
            BatchState::Pjrt { kv_k, kv_v, pos, capacity, occupied, decoded } => {
                let capacity = *capacity;
                if *decoded || *pos != 0 || occupied.iter().any(|&o| o) {
                    bail!(
                        "pjrt lock-step surface only admits into a fresh batch \
                         (the artifacts share a scalar pos0 across lanes)"
                    );
                }
                let plen = admissions[0].1.len();
                // empty lanes replay the first prompt: their kv and logits
                // are never read by any occupied lane
                let mut lane_prompts: Vec<&[u32]> = vec![admissions[0].1; capacity];
                for &(slot, prompt) in admissions {
                    if slot >= capacity {
                        bail!("slot {slot} out of range ({capacity} lanes)");
                    }
                    if occupied[slot] {
                        bail!("slot {slot} admitted twice");
                    }
                    if prompt.len() != plen {
                        bail!("pjrt lock-step admission requires prompt-length-aligned batches");
                    }
                    occupied[slot] = true;
                    lane_prompts[slot] = prompt;
                }
                let flat = self.chunked_prefill(&lane_prompts, capacity, kv_k, kv_v, pos)?;
                let v = self.cfg.vocab;
                Ok(admissions
                    .iter()
                    .map(|&(slot, _)| flat[slot * v..(slot + 1) * v].to_vec())
                    .collect())
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            bail!("decode over zero occupied slots");
        }
        match state {
            BatchState::PjrtLanes { lanes } => {
                let (_, exec, feed) = self.decode_exec(1)?;
                let (exec, feed) = (Arc::clone(exec), Arc::clone(feed));
                let v = self.cfg.vocab;
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(lane) = lanes.get_mut(st.slot).and_then(|l| l.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    let data = vec![
                        Value::I32(vec![st.token as i32]),
                        Value::I32(vec![lane.pos as i32]),
                        Value::F32(std::mem::take(&mut lane.kv_k)),
                        Value::F32(std::mem::take(&mut lane.kv_v)),
                    ];
                    let o = exec.run(&data, &feed)?;
                    out.push(o[0].as_f32()?[..v].to_vec());
                    lane.kv_k = match &o[1] {
                        Value::F32(x) => x.clone(),
                        _ => bail!("kv_k output not f32"),
                    };
                    lane.kv_v = match &o[2] {
                        Value::F32(x) => x.clone(),
                        _ => bail!("kv_v output not f32"),
                    };
                    lane.pos += 1;
                }
                Ok(out)
            }
            BatchState::Pjrt { kv_k, kv_v, pos, capacity, occupied, decoded } => {
                let capacity = *capacity;
                let (_, exec, feed) = self.decode_exec(capacity)?;
                let (exec, feed) = (Arc::clone(exec), Arc::clone(feed));
                // masked lanes (empty or released) replay a dummy token;
                // their logits and kv writes are never read
                let mut toks = vec![1i32; capacity];
                for st in tokens {
                    if st.slot >= capacity {
                        bail!("decode: slot {} out of range ({capacity} lanes)", st.slot);
                    }
                    if !occupied[st.slot] {
                        bail!("decode: slot {} is not occupied", st.slot);
                    }
                    toks[st.slot] = st.token as i32;
                }
                let data = vec![
                    Value::I32(toks),
                    Value::I32(vec![*pos as i32]),
                    Value::F32(std::mem::take(kv_k)),
                    Value::F32(std::mem::take(kv_v)),
                ];
                let out = exec.run(&data, &feed)?;
                let flat = out[0].as_f32()?;
                let v = self.cfg.vocab;
                let logits = tokens
                    .iter()
                    .map(|st| flat[st.slot * v..(st.slot + 1) * v].to_vec())
                    .collect();
                *kv_k = match &out[1] {
                    Value::F32(x) => x.clone(),
                    _ => bail!("kv_k output not f32"),
                };
                *kv_v = match &out[2] {
                    Value::F32(x) => x.clone(),
                    _ => bail!("kv_v output not f32"),
                };
                *pos += 1;
                *decoded = true;
                Ok(logits)
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        match state {
            BatchState::PjrtLanes { lanes } => {
                if slot >= lanes.len() {
                    bail!("release: slot {slot} out of range ({} lanes)", lanes.len());
                }
                lanes[slot] = None;
                Ok(())
            }
            BatchState::Pjrt { occupied, .. } => {
                if slot >= occupied.len() {
                    bail!("release: slot {slot} out of range ({} lanes)", occupied.len());
                }
                occupied[slot] = false;
                Ok(())
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn name(&self) -> String {
        format!(
            "pjrt{}:{}",
            if self.per_lane { "-lanes" } else { "" },
            self.label
        )
    }
}
