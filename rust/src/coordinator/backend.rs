//! Execution backends behind the coordinator: the native engine and the
//! PJRT AOT artifacts share one `Backend` trait so the serving loop,
//! benches and examples are backend-agnostic.
//!
//! The trait is shaped around a **persistent slot pool** (continuous
//! batching): `open_batch` allocates a decode surface with `capacity`
//! slots, `prefill_slot` admits one request into a free slot,
//! `decode` steps only the occupied slots, and `release_slot` frees a
//! finished slot so a queued request can be admitted mid-flight.
//!
//! Backends advertise how liberal their admission discipline is via
//! [`Backend::continuous`]:
//!
//! * [`NativeBackend`] — fully continuous: any free slot can be refilled
//!   at any time. [`Backend::decode`] steps every listed slot through
//!   **one weight-stationary batched engine step**
//!   ([`NativeEngine::step_batch`]): quantized weights stream once per
//!   step across all occupied slots instead of once per slot
//!   ([`NativeBackend::with_sequential_decode`] restores the per-slot
//!   baseline for A/B benching). By default every batch runs on a
//!   **paged KV pool**
//!   ([`crate::engine::kv::KvPagePool`]): slots map fixed-size pages on
//!   demand (resident bytes track true sequence length, pages-in-use is
//!   the admission-pressure signal), prompts sharing a cached prefix map
//!   the same read-only pages, and [`Backend::max_batch`] is the
//!   configurable [`NativeBackend::with_max_slots`] — decoupled from any
//!   compiled lane count. [`NativeBackend::with_dense`] restores the
//!   one-dense-`KvCache`-per-slot baseline.
//! * [`PjrtBackend`] in **per-lane** mode (`with_per_lane(true)`) — each
//!   slot is an independent batch-1 surface with its own position
//!   counter, so admission is continuous too (per-slot position
//!   tracking; mid-flight prefill falls back to single-step chunks when
//!   the prompt remainder is smaller than the compiled chunk sizes).
//! * [`PjrtBackend`] in **lock-step** mode (default) — one shared
//!   batch-N surface. The compiled artifacts carry a *scalar* `pos0`
//!   shared by every lane, so all lanes advance together: admission is
//!   only possible into a fresh surface with one shared prompt length
//!   (the aligned groups the `Batcher` forms). Released/empty lanes are
//!   masked: they are fed a dummy token whose logits and KV writes are
//!   never read by any occupied lane (lanes are independent in the
//!   batch dimension). Recompiling the artifacts with a per-lane
//!   position vector would lift this restriction — see ROADMAP.

use super::request::GenRequest;
use crate::engine::kv::{
    KvPagePool, KvPoolConfig, KvPoolStats, KvSlot, PagedKv, PagedKvRef, PagedSlotBatch, SlotBatch,
};
use crate::engine::native::EngineWs;
use crate::engine::{KvCache, NativeEngine, SubMode};
use crate::model::{Config, WeightStore};
use crate::runtime::exec::{build_weight_feed, Value};
use crate::runtime::{ExecRegistry, LoadedExec, Manifest};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// The last sampled token of an occupied slot, fed back for one decode
/// step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotToken {
    pub slot: usize,
    pub token: u32,
}

/// One per-slot PJRT surface (batch-1 artifacts, own position counter).
#[derive(Debug, Clone)]
pub struct PjrtLane {
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    pos: usize,
}

/// Per-batch generation state (opaque to the serving loop).
pub enum BatchState {
    /// Native engine, dense baseline: one independent full-capacity KV
    /// cache per occupied slot.
    Native { slots: Vec<Option<KvCache>> },
    /// Native engine, paged (default): one shared page pool, one paged
    /// view per occupied slot. Dropping the state drops the pool (and
    /// with it the prefix cache), so a serving run's reuse scope is its
    /// own pool.
    NativePaged { pool: KvPagePool, slots: Vec<Option<PagedKv>> },
    /// PJRT lock-step surface: shared KV buffers and a scalar position.
    Pjrt {
        kv_k: Vec<f32>,
        kv_v: Vec<f32>,
        pos: usize,
        capacity: usize,
        occupied: Vec<bool>,
        decoded: bool,
    },
    /// PJRT per-lane surfaces: independent batch-1 KV + position per slot.
    PjrtLanes { lanes: Vec<Option<PjrtLane>> },
}

pub trait Backend {
    fn cfg(&self) -> &Config;

    /// Largest compiled/supported slot count.
    fn max_batch(&self) -> usize;

    /// Whether a freed slot can be refilled while other slots keep
    /// decoding. Non-continuous backends only admit into a fresh surface
    /// (no decode steps yet) with one shared prompt length.
    fn continuous(&self) -> bool;

    /// Open a decode surface with `capacity` empty slots.
    fn open_batch(&mut self, capacity: usize) -> Result<BatchState>;

    /// Admit `prompt` into the free slot `slot`; returns the last-position
    /// logits (the distribution of the first generated token).
    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>>;

    /// Admit several equal-length prompts at once into distinct free
    /// slots of a fresh surface. Lock-step backends override this with a
    /// single batched prefill; the default loops [`Backend::prefill_slot`].
    fn prefill_slots(
        &mut self,
        state: &mut BatchState,
        admissions: &[(usize, &[u32])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(admissions.len());
        for &(slot, prompt) in admissions {
            out.push(self.prefill_slot(state, slot, prompt)?);
        }
        Ok(out)
    }

    /// Reserve whatever `slot` needs for its next decode step (for the
    /// paged native backend: the KV page the next position lands in,
    /// copy-on-write included). The serving loop calls this per slot
    /// before the batched [`Backend::decode`]; an error means the slot
    /// cannot advance (e.g. pool exhausted) and the loop finishes that
    /// one request with a terminal error instead of aborting.
    fn prepare_decode(&mut self, _state: &mut BatchState, _slot: usize) -> Result<()> {
        Ok(())
    }

    /// One decode step over the listed occupied slots: `tokens[i]` names a
    /// slot and its last sampled token. Returns next-token logits per
    /// entry, in the same order. Unlisted slots are untouched (native,
    /// per-lane) or masked (lock-step). Slots must have been
    /// [`Backend::prepare_decode`]d this step.
    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>>;

    /// Free `slot` so a queued request can be admitted into it.
    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()>;

    /// KV-pool counters for this batch, when the backend serves from a
    /// paged pool (None on dense/PJRT surfaces). The serving loop folds
    /// these into [`super::metrics::ServeMetrics`].
    fn kv_stats(&self, _state: &BatchState) -> Option<KvPoolStats> {
        None
    }

    fn name(&self) -> String;
}

/// Per-request admission validation against model limits.
pub fn validate_request(cfg: &Config, req: &GenRequest) -> Result<()> {
    if req.prompt.is_empty() {
        bail!("request {}: empty prompt", req.id);
    }
    if req.prompt.len() + req.max_new_tokens > cfg.max_seq {
        bail!(
            "request {}: prompt {} + gen {} exceeds max_seq {}",
            req.id,
            req.prompt.len(),
            req.max_new_tokens,
            cfg.max_seq
        );
    }
    Ok(())
}

/// Validate an aligned batch of requests against backend limits
/// (lock-step group admission).
pub fn validate_batch(backend: &dyn Backend, reqs: &[GenRequest]) -> Result<()> {
    if reqs.len() > backend.max_batch() {
        bail!(
            "batch of {} requests exceeds backend max batch {}",
            reqs.len(),
            backend.max_batch()
        );
    }
    let Some(first) = reqs.first() else { return Ok(()) };
    let plen = first.prompt.len();
    for r in reqs {
        validate_request(backend.cfg(), r)?;
        if r.prompt.len() != plen {
            bail!("batch is not prompt-length aligned");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Positions per KV page unless overridden by
/// [`NativeBackend::with_kv_pool`].
pub const DEFAULT_PAGE_SIZE: usize = 16;

pub struct NativeBackend {
    engine: NativeEngine,
    ws: EngineWs,
    label: String,
    /// paged pool (default) vs one dense cache per slot
    paged: bool,
    /// slot-pool width advertised as `max_batch` — decoupled from any
    /// compiled lane count on the native path
    max_slots: usize,
    page_size: usize,
    /// pool size in pages; 0 = worst case (`capacity * max_seq` worth,
    /// so decode can never exhaust the pool mid-flight)
    pool_pages: usize,
    /// A/B escape hatch: decode each listed slot with its own engine
    /// step (re-streaming the weights per slot) instead of the
    /// weight-stationary batched step.
    sequential_decode: bool,
}

impl NativeBackend {
    pub fn new(engine: NativeEngine, label: &str) -> NativeBackend {
        NativeBackend {
            engine,
            ws: EngineWs::default(),
            label: label.to_string(),
            paged: true,
            max_slots: 4,
            page_size: DEFAULT_PAGE_SIZE,
            pool_pages: 0,
            sequential_decode: false,
        }
    }

    pub fn from_checkpoint(path: &std::path::Path, mode: SubMode, label: &str) -> Result<NativeBackend> {
        let store = WeightStore::load(path)?;
        Ok(NativeBackend::new(NativeEngine::from_store(&store, mode)?, label))
    }

    /// Dense baseline: one full-capacity `KvCache` per slot, no paging,
    /// no prefix reuse (the pre-pool behaviour; kept for equivalence
    /// tests and the fig7 memory-budget comparison).
    pub fn with_dense(mut self) -> NativeBackend {
        self.paged = false;
        self
    }

    /// Slot-pool width (`max_batch`). The native engine decodes slots
    /// sequentially, so this bounds concurrency/occupancy accounting —
    /// with the paged pool it can exceed the old dense default of 4
    /// because short sequences no longer pin `max_seq` bytes each.
    pub fn with_max_slots(mut self, n: usize) -> NativeBackend {
        assert!(n > 0, "zero slots");
        self.max_slots = n;
        self
    }

    /// Explicit pool geometry: `page_size` positions per page and a hard
    /// budget of `n_pages` pages. With a finite budget, admissions that
    /// cannot get pages are shed gracefully (prefill returns an error
    /// and the coordinator emits a terminal `Error` event), and a slot
    /// starved mid-decode fails [`Backend::prepare_decode`] so the
    /// serving loop terminates just that request.
    pub fn with_kv_pool(mut self, page_size: usize, n_pages: usize) -> NativeBackend {
        assert!(page_size > 0 && n_pages > 0, "degenerate pool geometry");
        self.page_size = page_size;
        self.pool_pages = n_pages;
        self
    }

    /// Decode listed slots one engine step at a time instead of through
    /// the weight-stationary batched step — the pre-batched behaviour,
    /// kept as an A/B baseline for the fig7/microbench comparisons.
    /// Logits are bit-identical either way; only the weight traffic (and
    /// wall-clock) differs.
    pub fn with_sequential_decode(mut self) -> NativeBackend {
        self.sequential_decode = true;
        self
    }

    pub fn engine(&self) -> &NativeEngine {
        &self.engine
    }

    pub fn traffic(&self) -> &crate::engine::Traffic {
        &self.ws.traffic
    }

    pub fn reset_traffic(&mut self) {
        self.ws.traffic.reset();
    }

    /// The per-slot decode loop ([`NativeBackend::with_sequential_decode`]):
    /// one full engine step — and one full pass over the weights — per
    /// occupied slot.
    fn decode_sequential(
        &mut self,
        state: &mut BatchState,
        tokens: &[SlotToken],
    ) -> Result<Vec<Vec<f32>>> {
        // same contract as the batched path: a slot may be listed once
        // (double-stepping would silently advance its KV twice); slot
        // counts are small, so the quadratic scan beats allocating a
        // bitmap sized by a caller-supplied id
        for (idx, st) in tokens.iter().enumerate() {
            if tokens[..idx].iter().any(|p| p.slot == st.slot) {
                bail!("decode: slot {} listed twice", st.slot);
            }
        }
        // validate every slot before stepping any, like the batched path:
        // a mid-loop error must not leave earlier slots silently advanced
        match state {
            BatchState::Native { slots } => {
                for st in tokens {
                    let Some(kv) = slots.get(st.slot).and_then(|s| s.as_ref()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv cache full", st.slot);
                    }
                }
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let kv = slots[st.slot].as_mut().expect("validated above");
                    out.push(self.engine.decode_one(st.token, kv, &mut self.ws));
                }
                Ok(out)
            }
            BatchState::NativePaged { pool, slots } => {
                for st in tokens {
                    let Some(kv) = slots.get_mut(st.slot).and_then(|s| s.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv view full", st.slot);
                    }
                    // pages were reserved by prepare_decode; this is a
                    // no-op backstop for callers that skipped it
                    let pos = kv.len();
                    pool.ensure_range(kv, pos, pos + 1)
                        .with_context(|| format!("decoding slot {} at position {pos}", st.slot))?;
                }
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let kv = slots[st.slot].as_mut().expect("validated above");
                    let mut bound = PagedKvRef { pool: &mut *pool, kv };
                    out.push(self.engine.decode_one(st.token, &mut bound, &mut self.ws));
                }
                Ok(out)
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }
}

impl Backend for NativeBackend {
    fn cfg(&self) -> &Config {
        &self.engine.cfg
    }

    fn max_batch(&self) -> usize {
        self.max_slots
    }

    fn continuous(&self) -> bool {
        // every slot owns an independent KV view: admit any time.
        true
    }

    fn open_batch(&mut self, capacity: usize) -> Result<BatchState> {
        if capacity == 0 {
            bail!("zero-capacity batch");
        }
        if !self.paged {
            return Ok(BatchState::Native { slots: (0..capacity).map(|_| None).collect() });
        }
        let cfg = &self.engine.cfg;
        let pages_per_seq = (cfg.max_seq + self.page_size - 1) / self.page_size;
        let n_pages = if self.pool_pages > 0 { self.pool_pages } else { capacity * pages_per_seq };
        let pool = KvPagePool::new(KvPoolConfig::new(
            cfg.n_layers,
            cfg.n_heads,
            cfg.head_dim(),
            self.page_size,
            n_pages,
        ));
        Ok(BatchState::NativePaged { pool, slots: (0..capacity).map(|_| None).collect() })
    }

    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        match state {
            BatchState::Native { slots } => {
                if slot >= slots.len() {
                    bail!("slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("slot {slot} is already occupied");
                }
                let cfg = &self.engine.cfg;
                let mut kv = KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim());
                let logits = self.engine.prefill(prompt, &mut kv, &mut self.ws);
                slots[slot] = Some(kv);
                Ok(logits)
            }
            BatchState::NativePaged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("slot {slot} is already occupied");
                }
                let mut kv = pool.new_kv(self.engine.cfg.max_seq);
                // map any cached page-aligned prefix, then make the rest
                // of the prompt writable (copy-on-write included) before
                // the engine runs — exhaustion sheds here, not mid-step
                let reused = pool.adopt_prefix(&mut kv, prompt);
                if let Err(e) = pool.ensure_range(&mut kv, reused, prompt.len()) {
                    pool.release_kv(&mut kv);
                    return Err(e)
                        .with_context(|| format!("admitting a {}-token prompt", prompt.len()));
                }
                pool.record_reuse(reused);
                let logits = {
                    let mut bound = PagedKvRef { pool: &mut *pool, kv: &mut kv };
                    self.engine.prefill(&prompt[reused..], &mut bound, &mut self.ws)
                };
                pool.register_prefix(&kv, prompt);
                slots[slot] = Some(kv);
                Ok(logits)
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        if self.sequential_decode {
            return self.decode_sequential(state, tokens);
        }
        match state {
            BatchState::Native { slots } => {
                // distinct slots own distinct caches: split the borrows
                let mut refs: Vec<Option<&mut KvCache>> =
                    slots.iter_mut().map(|s| s.as_mut()).collect();
                let mut batch: Vec<&mut dyn KvSlot> = Vec::with_capacity(tokens.len());
                let mut toks = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(kv) = refs.get_mut(st.slot).and_then(|r| r.take()) else {
                        bail!("decode: slot {} is not occupied (or listed twice)", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv cache full", st.slot);
                    }
                    toks.push(st.token);
                    batch.push(kv as &mut dyn KvSlot);
                }
                let mut sb = SlotBatch { slots: batch };
                Ok(self.engine.step_batch(&toks, &mut sb, &mut self.ws))
            }
            BatchState::NativePaged { pool, slots } => {
                // pages were reserved by prepare_decode; this is a no-op
                // backstop for callers that skipped it
                for st in tokens {
                    let Some(kv) = slots.get_mut(st.slot).and_then(|s| s.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    if kv.remaining() == 0 {
                        bail!("slot {}: kv view full", st.slot);
                    }
                    let pos = kv.len();
                    pool.ensure_range(kv, pos, pos + 1)
                        .with_context(|| format!("decoding slot {} at position {pos}", st.slot))?;
                }
                let mut refs: Vec<Option<&mut PagedKv>> =
                    slots.iter_mut().map(|s| s.as_mut()).collect();
                let mut sel: Vec<&mut PagedKv> = Vec::with_capacity(tokens.len());
                let mut toks = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(kv) = refs.get_mut(st.slot).and_then(|r| r.take()) else {
                        bail!("decode: slot {} listed twice", st.slot);
                    };
                    toks.push(st.token);
                    sel.push(kv);
                }
                let mut sb = PagedSlotBatch { pool, slots: sel };
                Ok(self.engine.step_batch(&toks, &mut sb, &mut self.ws))
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }

    fn prepare_decode(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        let BatchState::NativePaged { pool, slots } = state else {
            return Ok(()); // dense caches are preallocated
        };
        let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
            bail!("prepare_decode: slot {slot} is not occupied");
        };
        if kv.remaining() == 0 {
            bail!("slot {slot}: kv view full");
        }
        let pos = kv.len();
        pool.ensure_range(kv, pos, pos + 1)
            .with_context(|| format!("slot {slot} cannot advance past position {pos}"))
    }

    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        match state {
            BatchState::Native { slots } => {
                if slot >= slots.len() {
                    bail!("release: slot {slot} out of range ({} slots)", slots.len());
                }
                slots[slot] = None;
                Ok(())
            }
            BatchState::NativePaged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("release: slot {slot} out of range ({} slots)", slots.len());
                }
                if let Some(mut kv) = slots[slot].take() {
                    // pages shared with the prefix cache (or siblings)
                    // stay resident; private pages return to the free list
                    pool.release_kv(&mut kv);
                }
                Ok(())
            }
            _ => bail!("native backend got a foreign batch state"),
        }
    }

    fn kv_stats(&self, state: &BatchState) -> Option<KvPoolStats> {
        match state {
            BatchState::NativePaged { pool, .. } => Some(pool.stats()),
            _ => None,
        }
    }

    fn name(&self) -> String {
        format!("native:{}", self.label)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

struct PjrtArtifacts {
    /// prefill execs by (batch, t_step), t_steps descending
    prefill: Vec<(usize, usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
    /// decode execs by batch
    decode: Vec<(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
}

pub struct PjrtBackend {
    cfg: Config,
    label: String,
    arts: PjrtArtifacts,
    batches: Vec<usize>,
    kv_numel: usize,
    kv_shape: Vec<usize>,
    per_lane: bool,
}

impl PjrtBackend {
    /// Load + compile the serve artifacts for `(model, checkpoint)`.
    pub fn new(registry: &mut ExecRegistry, store: &WeightStore,
               batches: &[usize], label: &str) -> Result<PjrtBackend> {
        let cfg = store.cfg.clone();
        let quantized = store.is_quantized();
        let model = cfg.name.clone();
        let mut prefill = Vec::new();
        let mut decode = Vec::new();
        for &b in batches {
            for t_step in [128usize, 32] {
                let name = format!(
                    "prefill_{model}_{}_b{b}_t{t_step}",
                    if quantized { "q" } else { "fp" }
                );
                let exec = registry.load(&name)?;
                let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
                prefill.push((b, t_step, exec, feed));
            }
            let name = Manifest::step_name("decode", &model, quantized, b);
            let exec = registry.load(&name)?;
            let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
            decode.push((b, exec, feed));
        }
        // kv shape from the b=smallest decode spec, scaled per batch at use
        let kv_spec = decode[0]
            .1
            .spec
            .inputs
            .iter()
            .find(|t| t.name == "kv_k")
            .context("decode artifact missing kv_k input")?
            .clone();
        Ok(PjrtBackend {
            cfg,
            label: label.to_string(),
            arts: PjrtArtifacts { prefill, decode },
            batches: batches.to_vec(),
            kv_numel: kv_spec.numel(),
            kv_shape: kv_spec.shape,
            per_lane: false,
        })
    }

    /// Per-lane mode: every slot becomes an independent batch-1 surface
    /// with its own position counter, enabling continuous (mid-flight)
    /// admission at the cost of lane-sequential execution. Requires
    /// batch-1 artifacts.
    pub fn with_per_lane(mut self, on: bool) -> PjrtBackend {
        self.per_lane = on;
        self
    }

    fn kv_len_for(&self, capacity: usize) -> usize {
        // kv shape [L, B, Tm, H, hd] recorded for the smallest batch
        let base_b = self.kv_shape[1];
        self.kv_numel / base_b * capacity
    }

    fn decode_exec(&self, capacity: usize) -> Result<&(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)> {
        self.arts
            .decode
            .iter()
            .find(|(b, _, _)| *b == capacity)
            .with_context(|| format!("no decode artifact for batch {capacity}"))
    }

    /// Run the chunked prefill (128s, then 32s, then single decode steps)
    /// over a `capacity`-lane surface; every lane consumes one of the
    /// equal-length `lane_prompts` this call. Returns the last-chunk
    /// logits, flat `[capacity * vocab]`.
    fn chunked_prefill(&self, lane_prompts: &[&[u32]], capacity: usize,
                       kv_k: &mut Vec<f32>, kv_v: &mut Vec<f32>, pos: &mut usize)
                       -> Result<Vec<f32>> {
        if lane_prompts.len() != capacity {
            bail!("chunked_prefill: {} lane prompts for {capacity} lanes", lane_prompts.len());
        }
        let plen = lane_prompts[0].len();
        if lane_prompts.iter().any(|p| p.len() != plen) {
            bail!("chunked_prefill: lane prompts are not length-aligned");
        }
        let mut consumed = 0usize;
        let mut last_logits: Vec<f32> = Vec::new();
        while consumed < plen {
            let rem = plen - consumed;
            let chunk = self
                .arts
                .prefill
                .iter()
                .filter(|(b, t, _, _)| *b == capacity && *t <= rem)
                .map(|(_, t, _, _)| *t)
                .max();
            let (exec, feed, step) = match chunk {
                Some(t) => {
                    let (_, _, e, f) = self
                        .arts
                        .prefill
                        .iter()
                        .find(|(b, tt, _, _)| *b == capacity && *tt == t)
                        .unwrap();
                    (Arc::clone(e), Arc::clone(f), t)
                }
                None => {
                    // remainder smaller than any compiled chunk: fall back
                    // to single-step prefill through the decode artifact
                    let (_, e, f) = self.decode_exec(capacity)?;
                    (Arc::clone(e), Arc::clone(f), 1)
                }
            };
            // tokens [capacity, step]
            let mut toks = Vec::with_capacity(capacity * step);
            for prompt in lane_prompts {
                toks.extend(prompt[consumed..consumed + step].iter().map(|&t| t as i32));
            }
            let data = vec![
                Value::I32(toks),
                Value::I32(vec![*pos as i32]),
                Value::F32(std::mem::take(kv_k)),
                Value::F32(std::mem::take(kv_v)),
            ];
            let out = exec.run(&data, &feed)?;
            last_logits = out[0].as_f32()?.to_vec();
            *kv_k = match &out[1] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_k output not f32"),
            };
            *kv_v = match &out[2] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_v output not f32"),
            };
            *pos += step;
            consumed += step;
        }
        Ok(last_logits)
    }
}

impl Backend for PjrtBackend {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        *self.batches.iter().max().unwrap_or(&1)
    }

    fn continuous(&self) -> bool {
        self.per_lane
    }

    fn open_batch(&mut self, capacity: usize) -> Result<BatchState> {
        if capacity == 0 {
            bail!("zero-capacity batch");
        }
        if self.per_lane {
            if !self.batches.contains(&1) {
                bail!("per-lane pjrt serving requires batch-1 artifacts");
            }
            if capacity > self.max_batch() {
                bail!("capacity {capacity} exceeds compiled max batch {}", self.max_batch());
            }
            Ok(BatchState::PjrtLanes { lanes: (0..capacity).map(|_| None).collect() })
        } else {
            if !self.batches.contains(&capacity) {
                bail!("no compiled artifacts for batch {capacity}");
            }
            Ok(BatchState::Pjrt {
                kv_k: vec![0f32; self.kv_len_for(capacity)],
                kv_v: vec![0f32; self.kv_len_for(capacity)],
                pos: 0,
                capacity,
                occupied: vec![false; capacity],
                decoded: false,
            })
        }
    }

    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        match state {
            BatchState::PjrtLanes { lanes } => {
                if slot >= lanes.len() {
                    bail!("slot {slot} out of range ({} lanes)", lanes.len());
                }
                if lanes[slot].is_some() {
                    bail!("slot {slot} is already occupied");
                }
                let mut lane = PjrtLane {
                    kv_k: vec![0f32; self.kv_len_for(1)],
                    kv_v: vec![0f32; self.kv_len_for(1)],
                    pos: 0,
                };
                let logits = self.chunked_prefill(
                    &[prompt], 1, &mut lane.kv_k, &mut lane.kv_v, &mut lane.pos,
                )?;
                lanes[slot] = Some(lane);
                Ok(logits)
            }
            BatchState::Pjrt { .. } => {
                let mut out = self.prefill_slots(state, &[(slot, prompt)])?;
                Ok(out.remove(0))
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn prefill_slots(
        &mut self,
        state: &mut BatchState,
        admissions: &[(usize, &[u32])],
    ) -> Result<Vec<Vec<f32>>> {
        if admissions.is_empty() {
            return Ok(Vec::new());
        }
        match state {
            // per-lane surfaces are independent: admit one by one
            BatchState::PjrtLanes { .. } => {
                let mut out = Vec::with_capacity(admissions.len());
                for &(slot, prompt) in admissions {
                    out.push(self.prefill_slot(state, slot, prompt)?);
                }
                Ok(out)
            }
            BatchState::Pjrt { kv_k, kv_v, pos, capacity, occupied, decoded } => {
                let capacity = *capacity;
                if *decoded || *pos != 0 || occupied.iter().any(|&o| o) {
                    bail!(
                        "pjrt lock-step surface only admits into a fresh batch \
                         (the artifacts share a scalar pos0 across lanes)"
                    );
                }
                let plen = admissions[0].1.len();
                // empty lanes replay the first prompt: their kv and logits
                // are never read by any occupied lane
                let mut lane_prompts: Vec<&[u32]> = vec![admissions[0].1; capacity];
                for &(slot, prompt) in admissions {
                    if slot >= capacity {
                        bail!("slot {slot} out of range ({capacity} lanes)");
                    }
                    if occupied[slot] {
                        bail!("slot {slot} admitted twice");
                    }
                    if prompt.len() != plen {
                        bail!("pjrt lock-step admission requires prompt-length-aligned batches");
                    }
                    occupied[slot] = true;
                    lane_prompts[slot] = prompt;
                }
                let flat = self.chunked_prefill(&lane_prompts, capacity, kv_k, kv_v, pos)?;
                let v = self.cfg.vocab;
                Ok(admissions
                    .iter()
                    .map(|&(slot, _)| flat[slot * v..(slot + 1) * v].to_vec())
                    .collect())
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            bail!("decode over zero occupied slots");
        }
        match state {
            BatchState::PjrtLanes { lanes } => {
                let (_, exec, feed) = self.decode_exec(1)?;
                let (exec, feed) = (Arc::clone(exec), Arc::clone(feed));
                let v = self.cfg.vocab;
                let mut out = Vec::with_capacity(tokens.len());
                for st in tokens {
                    let Some(lane) = lanes.get_mut(st.slot).and_then(|l| l.as_mut()) else {
                        bail!("decode: slot {} is not occupied", st.slot);
                    };
                    let data = vec![
                        Value::I32(vec![st.token as i32]),
                        Value::I32(vec![lane.pos as i32]),
                        Value::F32(std::mem::take(&mut lane.kv_k)),
                        Value::F32(std::mem::take(&mut lane.kv_v)),
                    ];
                    let o = exec.run(&data, &feed)?;
                    out.push(o[0].as_f32()?[..v].to_vec());
                    lane.kv_k = match &o[1] {
                        Value::F32(x) => x.clone(),
                        _ => bail!("kv_k output not f32"),
                    };
                    lane.kv_v = match &o[2] {
                        Value::F32(x) => x.clone(),
                        _ => bail!("kv_v output not f32"),
                    };
                    lane.pos += 1;
                }
                Ok(out)
            }
            BatchState::Pjrt { kv_k, kv_v, pos, capacity, occupied, decoded } => {
                let capacity = *capacity;
                let (_, exec, feed) = self.decode_exec(capacity)?;
                let (exec, feed) = (Arc::clone(exec), Arc::clone(feed));
                // masked lanes (empty or released) replay a dummy token;
                // their logits and kv writes are never read
                let mut toks = vec![1i32; capacity];
                for st in tokens {
                    if st.slot >= capacity {
                        bail!("decode: slot {} out of range ({capacity} lanes)", st.slot);
                    }
                    if !occupied[st.slot] {
                        bail!("decode: slot {} is not occupied", st.slot);
                    }
                    toks[st.slot] = st.token as i32;
                }
                let data = vec![
                    Value::I32(toks),
                    Value::I32(vec![*pos as i32]),
                    Value::F32(std::mem::take(kv_k)),
                    Value::F32(std::mem::take(kv_v)),
                ];
                let out = exec.run(&data, &feed)?;
                let flat = out[0].as_f32()?;
                let v = self.cfg.vocab;
                let logits = tokens
                    .iter()
                    .map(|st| flat[st.slot * v..(st.slot + 1) * v].to_vec())
                    .collect();
                *kv_k = match &out[1] {
                    Value::F32(x) => x.clone(),
                    _ => bail!("kv_k output not f32"),
                };
                *kv_v = match &out[2] {
                    Value::F32(x) => x.clone(),
                    _ => bail!("kv_v output not f32"),
                };
                *pos += 1;
                *decoded = true;
                Ok(logits)
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> Result<()> {
        match state {
            BatchState::PjrtLanes { lanes } => {
                if slot >= lanes.len() {
                    bail!("release: slot {slot} out of range ({} lanes)", lanes.len());
                }
                lanes[slot] = None;
                Ok(())
            }
            BatchState::Pjrt { occupied, .. } => {
                if slot >= occupied.len() {
                    bail!("release: slot {slot} out of range ({} lanes)", occupied.len());
                }
                occupied[slot] = false;
                Ok(())
            }
            _ => bail!("pjrt backend got a foreign batch state"),
        }
    }

    fn name(&self) -> String {
        format!(
            "pjrt{}:{}",
            if self.per_lane { "-lanes" } else { "" },
            self.label
        )
    }
}
