//! Minimal loopback HTTP/SSE client: what the load harness and the e2e
//! tests speak to the server with (std-only, one connection per request).

use super::{http, sse};
use crate::coordinator::request::{GenRequest, Priority};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// Outcome of one `POST /v1/generate` call, with client-side receipt
/// timestamps (the HTTP-mode TTFT/ITL numbers come from these).
#[derive(Debug)]
pub struct GenOutcome {
    pub status: u16,
    /// streamed token ids in arrival order
    pub tokens: Vec<u32>,
    /// receipt time of each token frame
    pub token_times: Vec<Instant>,
    /// `done` payload (completed requests only)
    pub done: Option<Json>,
    /// error-response body or `error` event message
    pub error: Option<String>,
    /// just before the request bytes hit the socket
    pub sent_at: Instant,
    /// when the terminal frame (or error response) was read
    pub finished_at: Instant,
    /// server-assigned id from the `X-Request-Id` response header
    /// (present on every response that reached admission, 4xx included)
    pub request_id: Option<u64>,
}

/// Serialize a [`GenRequest`] as a `/v1/generate` POST body (the id is
/// server-assigned and deliberately not sent).
pub fn gen_body(req: &GenRequest) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("prompt", Json::Arr(req.prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("max_new_tokens", req.max_new_tokens.into()),
    ];
    if req.params.is_sampled() {
        fields.push(("temperature", (req.params.temperature as f64).into()));
        fields.push(("top_k", req.params.top_k.into()));
        fields.push(("top_p", (req.params.top_p as f64).into()));
        fields.push(("seed", (req.params.seed as f64).into()));
    }
    if let Some(st) = req.stop_token {
        fields.push(("stop_token", (st as f64).into()));
    }
    if req.class != Priority::default() {
        fields.push(("priority", req.class.name().into()));
    }
    Json::obj(fields)
}

/// POST a generate request and consume its SSE stream.
/// `disconnect_after` hard-drops the connection after that many token
/// frames (mid-stream client-disconnect testing); `None` reads through
/// to the terminal event.
pub fn post_generate(
    addr: SocketAddr,
    body: &Json,
    disconnect_after: Option<usize>,
) -> Result<GenOutcome> {
    let mut stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let payload = body.to_string_compact();
    let sent_at = Instant::now();
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{payload}",
        payload.len()
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut reader)?;
    let request_id = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-request-id"))
        .and_then(|(_, v)| v.parse::<u64>().ok());
    let mut out = GenOutcome {
        status,
        tokens: Vec::new(),
        token_times: Vec::new(),
        done: None,
        error: None,
        sent_at,
        finished_at: Instant::now(),
        request_id,
    };
    if status != 200 {
        let body = read_sized_body(&mut reader, &headers)?;
        let msg = Json::parse(&body)
            .ok()
            .and_then(|j| j.get("error").and_then(Json::as_str).map(str::to_string));
        out.error = Some(msg.unwrap_or(body));
        out.finished_at = Instant::now();
        return Ok(out);
    }
    // Blank-line-delimited incremental parse: frames split across read
    // boundaries (or coalesced into one read) parse identically, where a
    // per-read interpretation would mis-frame them.
    let mut parser = sse::SseParser::new();
    while let Some(ev) = sse::next_from(&mut reader, &mut parser)? {
        match ev.event.as_str() {
            "message" => {
                let j = Json::parse(&ev.data).map_err(|e| anyhow!("bad token frame: {e}"))?;
                let tok = j
                    .get("token")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow!("token frame without token id"))?;
                out.tokens.push(tok as u32);
                out.token_times.push(Instant::now());
                if disconnect_after.is_some_and(|n| out.tokens.len() >= n) {
                    // dropping the stream mid-flight aborts the
                    // connection — the server sees the next write fail
                    out.finished_at = Instant::now();
                    return Ok(out);
                }
            }
            "done" => {
                out.done =
                    Some(Json::parse(&ev.data).map_err(|e| anyhow!("bad done frame: {e}"))?);
                break;
            }
            "error" => {
                let msg = Json::parse(&ev.data)
                    .ok()
                    .and_then(|j| j.get("message").and_then(Json::as_str).map(str::to_string));
                out.error = Some(msg.unwrap_or(ev.data));
                break;
            }
            _ => {}
        }
    }
    out.finished_at = Instant::now();
    Ok(out)
}

/// Plain GET; returns (status, body text).
pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut reader)?;
    let body = read_sized_body(&mut reader, &headers)?;
    Ok((status, body))
}

/// Read a Content-Length body (or to EOF without one).
fn read_sized_body(r: &mut impl BufRead, headers: &[(String, String)]) -> Result<String> {
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse::<usize>().ok());
    let mut buf = Vec::new();
    match len {
        Some(n) => {
            buf.resize(n, 0);
            r.read_exact(&mut buf)?;
        }
        None => {
            r.read_to_end(&mut buf)?;
        }
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}
