//! Open-loop load harness: replay a seeded [`Workload`] trace against
//! the coordinator and record per-request TTFT, inter-token latency and
//! end-to-end latency.
//!
//! Two modes over the **same** trace:
//! * [`run_in_process`] — submit through [`CoordinatorClient`] directly
//!   (the floor: scheduler + engine only),
//! * [`run_http`] — submit over HTTP loopback through the full server
//!   (socket accept, HTTP parse, SSE framing), so the server tax is the
//!   measured difference between the two mode rows in `BENCH_serve.json`.
//!
//! Open loop means arrivals are paced by the trace clock, never by
//! completions — when the server falls behind, requests pile up and the
//! tail percentiles show it (a closed loop would politely wait and hide
//! the overload).

use super::client;
use crate::coordinator::request::GenEvent;
use crate::coordinator::server::CoordinatorClient;
use crate::coordinator::workload::Workload;
use crate::util::json::Json;
use crate::util::Hist;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-request latency record.
#[derive(Debug, Clone)]
pub struct ReqRecord {
    pub id: u64,
    /// completed with a terminal `done`
    pub ok: bool,
    /// shed by admission control (429 or a shed/exhausted error)
    pub shed: bool,
    /// the client disconnected mid-stream per the trace's chaos plan
    /// ([`crate::coordinator::workload::ReqMeta::drop_after`])
    pub dropped: bool,
    /// tokens streamed before the terminal event
    pub tokens: usize,
    /// submit → first token
    pub ttft_us: f64,
    /// gaps between consecutive token receipts
    pub itl_us: Vec<f64>,
    /// submit → terminal event
    pub e2e_us: f64,
}

impl ReqRecord {
    fn new(id: u64) -> ReqRecord {
        ReqRecord {
            id,
            ok: false,
            shed: false,
            dropped: false,
            tokens: 0,
            ttft_us: 0.0,
            itl_us: Vec::new(),
            e2e_us: 0.0,
        }
    }
}

/// One harness run over a trace.
#[derive(Debug)]
pub struct HarnessResult {
    pub mode: &'static str,
    /// per-request records, sorted by request id
    pub records: Vec<ReqRecord>,
    /// trace-start → last terminal event
    pub wall_s: f64,
}

impl HarnessResult {
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.ok).count()
    }

    pub fn shed(&self) -> usize {
        self.records.iter().filter(|r| r.shed).count()
    }

    /// Requests whose client disconnected mid-stream (chaos plan).
    pub fn dropped(&self) -> usize {
        self.records.iter().filter(|r| r.dropped).count()
    }

    /// Fraction of submitted requests shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.shed() as f64 / self.records.len() as f64
        }
    }

    /// Tokens per second delivered to requests that completed (shed and
    /// failed requests contribute nothing — goodput, not throughput).
    pub fn goodput_tps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        let toks: usize = self.records.iter().filter(|r| r.ok).map(|r| r.tokens).sum();
        toks as f64 / self.wall_s
    }

    /// One mode row for `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let done: Vec<&ReqRecord> = self.records.iter().filter(|r| r.ok).collect();
        let ttft: Vec<f64> = done.iter().filter(|r| r.tokens > 0).map(|r| r.ttft_us).collect();
        let itl: Vec<f64> = done.iter().flat_map(|r| r.itl_us.iter().copied()).collect();
        let e2e: Vec<f64> = done.iter().map(|r| r.e2e_us).collect();
        Json::obj(vec![
            ("mode", self.mode.into()),
            ("requests", self.records.len().into()),
            ("completed", done.len().into()),
            ("shed", self.shed().into()),
            ("dropped", self.dropped().into()),
            ("wall_s", self.wall_s.into()),
            ("goodput_tps", self.goodput_tps().into()),
            ("shed_rate", self.shed_rate().into()),
            ("ttft_us", pct_json(&ttft)),
            ("itl_us", pct_json(&itl)),
            ("e2e_us", pct_json(&e2e)),
        ])
    }
}

/// Latency summary with the percentile keys the CI gate asserts on,
/// now backed by the log-bucketed [`Hist`]: same `n`/`mean_us`/`p50_us`/
/// `p95_us`/`p99_us`/`max_us` keys (percentiles resolved to the bucket's
/// ~1.2x width), plus a sparse `buckets` array of `[upper_us, count]`
/// pairs so BENCH_serve.json captures distribution shape, not just
/// point summaries.
fn pct_json(xs: &[f64]) -> Json {
    let mut h = Hist::new();
    for &x in xs {
        h.record_us(x);
    }
    h.to_json()
}

/// Sleep until the trace clock reaches `arrival`.
fn pace(start: Instant, arrival: Duration) {
    let elapsed = start.elapsed();
    if arrival > elapsed {
        std::thread::sleep(arrival - elapsed);
    }
}

fn push_record(out: &Arc<Mutex<Vec<ReqRecord>>>, rec: ReqRecord) {
    out.lock().expect("harness records poisoned").push(rec);
}

fn finish(
    mode: &'static str,
    records: Arc<Mutex<Vec<ReqRecord>>>,
    start: Instant,
) -> HarnessResult {
    let wall_s = start.elapsed().as_secs_f64();
    let mut records = match Arc::try_unwrap(records) {
        Ok(m) => m.into_inner().expect("harness records poisoned"),
        Err(arc) => arc.lock().expect("harness records poisoned").clone(),
    };
    records.sort_by_key(|r| r.id);
    HarnessResult { mode, records, wall_s }
}

/// Replay the trace open-loop straight into the coordinator (no HTTP).
/// One consumer thread per request drains its event stream and stamps
/// receipt times.
pub fn run_in_process(client: &CoordinatorClient, workload: &Workload) -> HarnessResult {
    let records = Arc::new(Mutex::new(Vec::new()));
    let start = Instant::now();
    let mut joins = Vec::new();
    let trace = workload.requests.iter().zip(&workload.arrivals).zip(&workload.meta);
    for ((req, arrival), meta) in trace {
        pace(start, *arrival);
        let id = req.id;
        let drop_after = meta.drop_after;
        let submitted = Instant::now();
        let rx = client.submit(req.clone());
        let out = records.clone();
        joins.push(std::thread::spawn(move || {
            let mut rec = ReqRecord::new(id);
            let mut last: Option<Instant> = None;
            for ev in rx {
                match ev {
                    GenEvent::Token { .. } => {
                        let now = Instant::now();
                        match last {
                            None => rec.ttft_us = (now - submitted).as_secs_f64() * 1e6,
                            Some(prev) => rec.itl_us.push((now - prev).as_secs_f64() * 1e6),
                        }
                        last = Some(now);
                        rec.tokens += 1;
                        if drop_after.is_some_and(|n| rec.tokens >= n) {
                            // breaking out drops the receiver — the
                            // serving loop's next emit fails, exactly
                            // like a mid-stream client disconnect
                            rec.dropped = true;
                            break;
                        }
                    }
                    GenEvent::Done(_) => {
                        rec.ok = true;
                        break;
                    }
                    GenEvent::Error { message, .. } => {
                        rec.shed = super::overload_message(&message);
                        break;
                    }
                }
            }
            rec.e2e_us = submitted.elapsed().as_secs_f64() * 1e6;
            push_record(&out, rec);
        }));
    }
    for j in joins {
        let _ = j.join();
    }
    finish("in_process", records, start)
}

/// Replay the trace open-loop over HTTP loopback (one connection per
/// request, like real SSE clients).
pub fn run_http(addr: SocketAddr, workload: &Workload) -> HarnessResult {
    let records = Arc::new(Mutex::new(Vec::new()));
    let start = Instant::now();
    let mut joins = Vec::new();
    let trace = workload.requests.iter().zip(&workload.arrivals).zip(&workload.meta);
    for ((req, arrival), meta) in trace {
        pace(start, *arrival);
        let id = req.id;
        let drop_after = meta.drop_after;
        let body = client::gen_body(req);
        let out = records.clone();
        joins.push(std::thread::spawn(move || {
            let rec = match client::post_generate(addr, &body, drop_after) {
                Ok(o) => {
                    let mut rec = outcome_record(id, &o);
                    // a 200 that ended with neither `done` nor `error`
                    // is the planned mid-stream disconnect
                    rec.dropped = o.status == 200 && o.done.is_none() && o.error.is_none();
                    rec
                }
                Err(_) => ReqRecord::new(id), // connect/read failure: not ok, not shed
            };
            push_record(&out, rec);
        }));
    }
    for j in joins {
        let _ = j.join();
    }
    finish("http", records, start)
}

fn outcome_record(id: u64, o: &client::GenOutcome) -> ReqRecord {
    let mut rec = ReqRecord::new(id);
    rec.ok = o.done.is_some();
    rec.shed = o.status == 429 || o.status == 503;
    rec.tokens = o.tokens.len();
    rec.e2e_us = (o.finished_at - o.sent_at).as_secs_f64() * 1e6;
    if let Some(err) = &o.error {
        rec.shed = rec.shed || super::overload_message(err);
    }
    if let Some(&first) = o.token_times.first() {
        rec.ttft_us = (first - o.sent_at).as_secs_f64() * 1e6;
    }
    for p in o.token_times.windows(2) {
        rec.itl_us.push((p[1] - p[0]).as_secs_f64() * 1e6);
    }
    rec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let mut res = HarnessResult { mode: "in_process", records: Vec::new(), wall_s: 2.0 };
        assert_eq!(res.shed_rate(), 0.0);
        let mut a = ReqRecord::new(1);
        a.ok = true;
        a.tokens = 10;
        a.ttft_us = 100.0;
        a.itl_us = vec![10.0, 20.0];
        a.e2e_us = 500.0;
        let mut b = ReqRecord::new(2);
        b.shed = true;
        res.records = vec![a, b];
        assert_eq!(res.completed(), 1);
        assert_eq!(res.shed(), 1);
        assert!((res.shed_rate() - 0.5).abs() < 1e-9);
        assert!((res.goodput_tps() - 5.0).abs() < 1e-9);
        let j = res.to_json();
        assert_eq!(j.get("mode").and_then(Json::as_str), Some("in_process"));
        assert_eq!(j.get("completed").and_then(Json::as_usize), Some(1));
        for lat in ["ttft_us", "itl_us", "e2e_us"] {
            let l = j.get(lat).unwrap();
            for k in ["n", "mean_us", "p50_us", "p95_us", "p99_us", "max_us", "buckets"] {
                assert!(l.get(k).is_some(), "{lat} missing {k}");
            }
            assert!(l.get("buckets").unwrap().as_arr().is_some());
        }
        assert!(res.shed_rate() <= 1.0);
    }
}
