//! Layer-4 serving front end: a std-only HTTP/1.1 + SSE server over the
//! spawned coordinator.
//!
//! The workspace builds offline against vendored shims, so the server is
//! hand-rolled on `std::net::TcpListener` — thread-per-connection behind
//! a bounded accept pool, no async runtime. That is a feature: the whole
//! request path (socket → JSON body → [`CoordinatorClient::submit`] →
//! SSE frames) is ~4 small modules of inspectable code.
//!
//! Routes:
//! * `POST /v1/generate` — JSON body (`prompt`, `max_new_tokens`,
//!   optional `temperature`/`top_k`/`top_p`/`seed`/`stop_token`/
//!   `priority`, the latter one of `interactive`/`standard`/`batch`) →
//!   an SSE stream: one `data:` frame per sampled token, then a
//!   terminal `event: done` (the full [`GenResponse`], including
//!   `queue_us`/`prefill_us` timing) or `event: error` frame. The
//!   **first** coordinator event decides the HTTP status: a shed /
//!   pool-exhausted request answers `429`, an invalid one `400`, and
//!   only a request that actually streams opens a `200`. Every
//!   response that reached admission — 200, 400 and 429 alike —
//!   carries the request's stable id as an `X-Request-Id` header (and
//!   in the terminal frame payload), the same id the flight recorder
//!   and metrics attribute by.
//! * `GET /metrics` — live [`ServeMetrics`] snapshot as JSON;
//!   `GET /metrics?format=prometheus` renders the same snapshot in
//!   Prometheus text exposition format 0.0.4.
//! * `GET /debug/trace` — drain the flight recorder and render
//!   Chrome trace-event JSON (load it in Perfetto / `chrome://tracing`;
//!   one lane per slot plus one per recording thread). Draining
//!   consumes: two consecutive fetches return disjoint events.
//!   Concurrent scrapers serialize; the one that lost the race gets
//!   `otherData.partial: true` plus the winner's drain window instead
//!   of silently receiving half the stream.
//! * `GET /healthz` — liveness probe: build version, uptime seconds
//!   and the current degradation level.
//! * `POST /admin/shutdown` — request a graceful shutdown. Gated on the
//!   peer address: only loopback connections are honoured (`403`
//!   otherwise). Sets a flag the embedding process polls via
//!   [`Server::shutdown_requested`]; the route itself does not tear the
//!   server down, so in-flight streams keep draining.
//!
//! A client that disconnects mid-stream is detected by the failed SSE
//! write: the connection thread drops its event receiver, the serving
//! loop's next emit fails, and the request's slot + KV pages are
//! reclaimed (counted in [`ServeMetrics::cancellations`]).
//!
//! [`GenResponse`]: crate::coordinator::request::GenResponse
//! [`ServeMetrics`]: crate::coordinator::metrics::ServeMetrics
//! [`ServeMetrics::cancellations`]: crate::coordinator::metrics::ServeMetrics::cancellations

pub mod client;
pub mod harness;
pub mod http;
pub mod sse;

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::prom;
use crate::coordinator::request::{GenEvent, GenRequest, GenResponse, Priority};
use crate::coordinator::server::{CoordinatorClient, CoordinatorHandle};
use crate::trace;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

pub use client::{gen_body, post_generate, GenOutcome};
pub use harness::{run_http, run_in_process, HarnessResult, ReqRecord};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`])
    pub addr: String,
    /// connections served concurrently before new ones answer 503
    pub max_connections: usize,
    /// request body cap in bytes (a prompt at 7 bytes/token JSON is far
    /// below this)
    pub max_body_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_connections: 64,
            max_body_bytes: 1 << 20,
        }
    }
}

/// The running server: an accept-loop thread plus one thread per live
/// connection, all submitting through [`CoordinatorClient`] clones.
pub struct Server {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_req: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    accept: Option<std::thread::JoinHandle<()>>,
    handle: CoordinatorHandle,
}

impl Server {
    /// Bind and start serving. Takes ownership of the coordinator handle;
    /// [`Server::shutdown`] drains and returns the final metrics.
    pub fn start(handle: CoordinatorHandle, cfg: &ServeConfig) -> Result<Server> {
        let _ = server_epoch(); // pin the uptime epoch at first bind
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_req = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let client = handle.client();
        let (max_conn, max_body) = (cfg.max_connections, cfg.max_body_bytes);
        let accept = {
            let (stop, active) = (stop.clone(), active.clone());
            let shutdown_req = shutdown_req.clone();
            std::thread::spawn(move || {
                accept_loop(listener, client, stop, shutdown_req, active, max_conn, max_body)
            })
        };
        Ok(Server { local_addr, stop, shutdown_req, active, accept: Some(accept), handle })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A coordinator submit handle bypassing HTTP (the in-process
    /// harness mode measures against this).
    pub fn client(&self) -> CoordinatorClient {
        self.handle.client()
    }

    /// Currently served connections.
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// True once a loopback client has hit `POST /admin/shutdown`. The
    /// embedding process (e.g. `cmd serve`) polls this and then calls
    /// [`Server::shutdown`].
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_req.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: stop accepting, wait for in-flight streams to
    /// drain (bounded), then shut the coordinator down and return its
    /// final metrics.
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        while self.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.handle.shutdown()
    }
}

/// Process-wide serving epoch for `/healthz` uptime: pinned the first
/// time a [`Server`] binds (or on first health probe, whichever comes
/// first — either way monotone from then on).
fn server_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Split a request target into `(path, query)`; the query is `""` when
/// absent. Routing matches on the path, handlers inspect the query.
fn split_query(target: &str) -> (&str, &str) {
    match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    }
}

/// True when a query string selects Prometheus text exposition.
fn wants_prometheus(query: &str) -> bool {
    query.split('&').any(|kv| kv == "format=prometheus")
}

/// Decrements the live-connection counter even if the handler panics.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    client: CoordinatorClient,
    stop: Arc<AtomicBool>,
    shutdown_req: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    max_conn: usize,
    max_body: usize,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, peer)) => {
                if active.load(Ordering::SeqCst) >= max_conn {
                    // accept-pool overflow: connection-level shed
                    let _ = http::write_response(
                        &mut stream,
                        503,
                        "application/json",
                        b"{\"error\":\"connection pool exhausted\"}",
                    );
                    continue;
                }
                active.fetch_add(1, Ordering::SeqCst);
                let client = client.clone();
                let guard = ConnGuard(active.clone());
                let shutdown_req = shutdown_req.clone();
                std::thread::spawn(move || {
                    let _guard = guard;
                    handle_conn(stream, peer, &client, max_body, &shutdown_req);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    peer: SocketAddr,
    client: &CoordinatorClient,
    max_body: usize,
    shutdown_req: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let req = match http::read_request(&mut reader, max_body) {
        Ok(r) => r,
        Err(e) => {
            let _ = error_response(&mut writer, 400, &e.to_string());
            return;
        }
    };
    let (path, query) = split_query(&req.path);
    match (req.method.as_str(), path) {
        ("POST", "/v1/generate") => handle_generate(&mut writer, client, &req.body),
        ("GET", "/healthz") => {
            let degrade = match client.metrics() {
                Ok(m) => Json::Num(m.degrade_level as f64),
                Err(_) => Json::Null, // still alive even if the snapshot stalls
            };
            let body = Json::obj(vec![
                ("ok", true.into()),
                ("version", env!("CARGO_PKG_VERSION").into()),
                ("uptime_s", server_epoch().elapsed().as_secs_f64().into()),
                ("degrade_level", degrade),
            ])
            .to_string_compact();
            let _ = http::write_response(&mut writer, 200, "application/json", body.as_bytes());
        }
        ("GET", "/metrics") => match client.metrics() {
            Ok(m) if wants_prometheus(query) => {
                let body = prom::render(&m);
                let _ = http::write_response(
                    &mut writer,
                    200,
                    "text/plain; version=0.0.4",
                    body.as_bytes(),
                );
            }
            Ok(m) => {
                let body = m.to_json().to_string_pretty();
                let _ =
                    http::write_response(&mut writer, 200, "application/json", body.as_bytes());
            }
            Err(e) => {
                let _ = error_response(&mut writer, 500, &e.to_string());
            }
        },
        ("GET", "/debug/trace") => {
            // Drain-and-render: consumes the recorder's buffered events so
            // back-to-back fetches return disjoint windows. Concurrent
            // scrapers serialize on the recorder's drain lock; a loser's
            // document carries `otherData.partial` + the winner's window.
            let dump = trace::drain();
            let body = trace::chrome::to_chrome_json(&dump).to_string_compact();
            let _ = http::write_response(&mut writer, 200, "application/json", body.as_bytes());
        }
        ("POST", "/admin/shutdown") => {
            // control-plane route: honour it only from loopback peers so
            // a forwarded / exposed port cannot kill the server
            if peer.ip().is_loopback() {
                shutdown_req.store(true, Ordering::SeqCst);
                let _ = http::write_response(
                    &mut writer,
                    200,
                    "application/json",
                    b"{\"ok\":true,\"shutting_down\":true}",
                );
            } else {
                let _ = error_response(&mut writer, 403, "shutdown is loopback-only");
            }
        }
        ("GET", _) | ("POST", _) => {
            let _ = error_response(&mut writer, 404, "no such route");
        }
        _ => {
            let _ = error_response(&mut writer, 405, "method not allowed");
        }
    }
}

/// `POST /v1/generate`: parse, submit, map the first coordinator event
/// to an HTTP status, then stream SSE frames until the terminal event.
/// A failed frame write means the client disconnected — returning drops
/// the receiver, which cancels the request in the serving loop.
fn handle_generate(writer: &mut TcpStream, client: &CoordinatorClient, body: &[u8]) {
    let req = match parse_gen_request(body) {
        Ok(r) => r,
        Err(e) => {
            // parse failure: no id was ever assigned, so no X-Request-Id
            let _ = error_response(writer, 400, &e.to_string());
            return;
        }
    };
    let (id, rx) = client.submit_with_id(req);
    let id_header = [("X-Request-Id", id.to_string())];
    match rx.recv_timeout(Duration::from_secs(120)) {
        Err(_) => {
            let _ = error_response_for(writer, 500, "coordinator did not answer", id);
        }
        Ok(GenEvent::Error { message, .. }) => {
            let code = if overload_message(&message) { 429 } else { 400 };
            let _ = error_response_for(writer, code, &message, id);
        }
        Ok(first) => {
            if http::write_sse_head_with(writer, &id_header).is_err() {
                return;
            }
            let terminal = first.is_terminal();
            if write_event(writer, &first).is_err() || terminal {
                return;
            }
            for ev in rx.iter() {
                let terminal = ev.is_terminal();
                if write_event(writer, &ev).is_err() || terminal {
                    return;
                }
            }
        }
    }
}

/// Overload (shed) vs caller error: admission-queue sheds and KV-pool
/// exhaustion map to 429 Too Many Requests; everything else the caller
/// can fix maps to 400.
pub fn overload_message(message: &str) -> bool {
    let m = message.to_ascii_lowercase();
    m.contains("shed") || m.contains("queue full") || m.contains("exhaust")
}

fn error_response(w: &mut impl Write, code: u16, message: &str) -> std::io::Result<()> {
    let body = Json::obj(vec![("error", message.into())]).to_string_compact();
    http::write_response(w, code, "application/json", body.as_bytes())
}

/// [`error_response`] for a request that already has an admission id:
/// carries it both as `X-Request-Id` and in the body, so a 429/400 can
/// still be correlated with trace events and server logs.
fn error_response_for(
    w: &mut impl Write,
    code: u16,
    message: &str,
    id: u64,
) -> std::io::Result<()> {
    let body = Json::obj(vec![("error", message.into()), ("id", (id as f64).into())])
        .to_string_compact();
    http::write_response_with(
        w,
        code,
        "application/json",
        &[("X-Request-Id", id.to_string())],
        body.as_bytes(),
    )
}

/// Serialize one [`GenEvent`] as its SSE frame and flush it.
fn write_event(w: &mut impl Write, ev: &GenEvent) -> std::io::Result<()> {
    let frame = match ev {
        GenEvent::Token { id, index, token } => {
            let j = Json::obj(vec![
                ("id", (*id as f64).into()),
                ("index", (*index).into()),
                ("token", (*token as f64).into()),
            ]);
            sse::data_frame(&j.to_string_compact())
        }
        GenEvent::Done(r) => sse::event_frame("done", &response_json(r).to_string_compact()),
        GenEvent::Error { id, message } => {
            let j = Json::obj(vec![
                ("id", (*id as f64).into()),
                ("message", message.as_str().into()),
            ]);
            sse::event_frame("error", &j.to_string_compact())
        }
    };
    w.write_all(frame.as_bytes())?;
    w.flush()
}

/// The `done` frame payload.
fn response_json(r: &GenResponse) -> Json {
    Json::obj(vec![
        ("id", (r.id as f64).into()),
        ("prompt_len", r.prompt_len.into()),
        ("tokens", Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("ttft_us", r.ttft_us.into()),
        ("total_us", r.total_us.into()),
        ("decode_s", r.decode_s.into()),
        ("queue_us", r.queue_us.into()),
        ("prefill_us", r.prefill_us.into()),
    ])
}

/// Parse a `/v1/generate` body. Ids are server-assigned (a client-sent
/// `id` is ignored) so two HTTP clients can never collide in flight.
fn parse_gen_request(body: &[u8]) -> Result<GenRequest> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("body is not utf-8"))?;
    let j = Json::parse(text).map_err(|e| anyhow!("invalid json: {e}"))?;
    let prompt_field = j.get("prompt").ok_or_else(|| anyhow!("missing 'prompt'"))?;
    let prompt: Vec<u32> = prompt_field
        .as_arr()
        .ok_or_else(|| anyhow!("'prompt' must be an array of token ids"))?
        .iter()
        .map(|t| t.as_i64().map(|v| v as u32))
        .collect::<Option<Vec<u32>>>()
        .ok_or_else(|| anyhow!("'prompt' must contain numeric token ids"))?;
    let max_new = j
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing numeric 'max_new_tokens'"))?;
    let mut req = GenRequest::new(0, prompt, max_new);
    if let Some(t) = j.get("temperature").and_then(Json::as_f64) {
        req.params.temperature = t as f32;
    }
    if let Some(k) = j.get("top_k").and_then(Json::as_usize) {
        req.params.top_k = k;
    }
    if let Some(p) = j.get("top_p").and_then(Json::as_f64) {
        req.params.top_p = p as f32;
    }
    if let Some(s) = j.get("seed").and_then(Json::as_i64) {
        req.params.seed = s as u64;
    }
    if let Some(st) = j.get("stop_token").and_then(Json::as_i64) {
        req.stop_token = Some(st as u32);
    }
    if let Some(p) = j.get("priority") {
        let s = p.as_str().ok_or_else(|| anyhow!("'priority' must be a string"))?;
        req.class = Priority::parse(s).ok_or_else(|| {
            anyhow!("unknown 'priority' {s:?} (expected interactive|standard|batch)")
        })?;
    }
    Ok(req)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generate_body() {
        let body = br#"{"prompt":[1,2,3],"max_new_tokens":8,"temperature":0.5,"top_k":4}"#;
        let req = parse_gen_request(body).unwrap();
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.max_new_tokens, 8);
        assert!(req.params.is_sampled());
        assert_eq!(req.params.top_k, 4);
        assert!(parse_gen_request(b"{}").is_err());
        assert!(parse_gen_request(b"{\"prompt\":\"hi\",\"max_new_tokens\":4}").is_err());
        assert!(parse_gen_request(b"not json").is_err());
    }

    #[test]
    fn parses_priority_field() {
        let body = br#"{"prompt":[1],"max_new_tokens":2}"#;
        assert_eq!(parse_gen_request(body).unwrap().class, Priority::Standard);
        let body = br#"{"prompt":[1],"max_new_tokens":2,"priority":"interactive"}"#;
        assert_eq!(parse_gen_request(body).unwrap().class, Priority::Interactive);
        let body = br#"{"prompt":[1],"max_new_tokens":2,"priority":"batch"}"#;
        assert_eq!(parse_gen_request(body).unwrap().class, Priority::Batch);
        let body = br#"{"prompt":[1],"max_new_tokens":2,"priority":"urgent"}"#;
        assert!(parse_gen_request(body).is_err());
        let body = br#"{"prompt":[1],"max_new_tokens":2,"priority":3}"#;
        assert!(parse_gen_request(body).is_err());
    }

    #[test]
    fn splits_query_and_detects_prometheus() {
        assert_eq!(split_query("/metrics"), ("/metrics", ""));
        assert_eq!(split_query("/metrics?format=prometheus"), ("/metrics", "format=prometheus"));
        assert_eq!(split_query("/a?b=c&d=e"), ("/a", "b=c&d=e"));
        assert!(wants_prometheus("format=prometheus"));
        assert!(wants_prometheus("x=1&format=prometheus"));
        assert!(!wants_prometheus(""));
        assert!(!wants_prometheus("format=json"));
        assert!(!wants_prometheus("format=prometheus2"));
    }

    #[test]
    fn overload_classification() {
        assert!(overload_message("admission queue full: request shed"));
        assert!(overload_message("kv page pool exhausted"));
        assert!(!overload_message("prompt exceeds max_seq"));
        assert!(!overload_message("request id 3 is already in flight"));
    }
}
