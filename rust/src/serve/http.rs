//! Hand-rolled HTTP/1.1 primitives for the serving front end.
//!
//! Deliberately minimal — the workspace builds offline against vendored
//! shims, so there is no tokio/hyper to lean on. One request per
//! connection (`Connection: close` on every response): the serving
//! protocol is a single long-lived SSE stream per generation, so
//! keep-alive would buy nothing and complicate draining.

use anyhow::{bail, Result};
use std::io::{BufRead, Read, Write};

/// A parsed HTTP request head plus its (Content-Length-sized) body.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Read one request from the connection. Bounds: 100 headers, 8 KiB per
/// header line, `max_body` body bytes — a malformed or hostile peer gets
/// an error (the connection handler answers 400), never unbounded memory.
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<HttpRequest> {
    let line = read_crlf_line(r)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        bail!("malformed request line: {line:?}");
    }
    let mut headers = Vec::new();
    loop {
        let h = read_crlf_line(r)?;
        if h.is_empty() {
            break;
        }
        let Some((k, v)) = h.split_once(':') else { bail!("malformed header: {h:?}") };
        headers.push((k.trim().to_string(), v.trim().to_string()));
        if headers.len() > 100 {
            bail!("too many headers");
        }
    }
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .map_err(|_| anyhow::anyhow!("bad content-length"))?
        .unwrap_or(0);
    if len > max_body {
        bail!("body too large: {len} > {max_body}");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, headers, body })
}

/// One header line, CRLF (or bare LF) stripped, length-bounded.
fn read_crlf_line(r: &mut impl BufRead) -> Result<String> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte)? {
            0 => {
                if buf.is_empty() {
                    bail!("connection closed before request");
                }
                break;
            }
            _ => {
                if byte[0] == b'\n' {
                    break;
                }
                buf.push(byte[0]);
                if buf.len() > 8192 {
                    bail!("header line too long");
                }
            }
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| anyhow::anyhow!("non-utf8 header line"))
}

/// Reason phrase for the status codes this server emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete (non-streaming) response and flush it.
pub fn write_response(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_response_with(w, code, content_type, &[], body)
}

/// [`write_response`] with extra response headers (e.g. `X-Request-Id`).
pub fn write_response_with(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        code,
        status_text(code),
        content_type,
        body.len()
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"Connection: close\r\n\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write the response head that opens an SSE stream (the body follows as
/// events, terminated by connection close).
pub fn write_sse_head(w: &mut impl Write) -> std::io::Result<()> {
    write_sse_head_with(w, &[])
}

/// [`write_sse_head`] with extra response headers (e.g. `X-Request-Id`).
pub fn write_sse_head_with(
    w: &mut impl Write,
    extra_headers: &[(&str, String)],
) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n",
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"Connection: close\r\n\r\n")?;
    w.flush()
}

/// Read a response head from a client-side connection: status code plus
/// headers (the body handling depends on the content type).
pub fn read_response_head(r: &mut impl BufRead) -> Result<(u16, Vec<(String, String)>)> {
    let line = read_crlf_line(r)?;
    let mut parts = line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("malformed status line: {line:?}");
    }
    let code: u16 = parts.next().unwrap_or("").parse()?;
    let mut headers = Vec::new();
    loop {
        let h = read_crlf_line(r)?;
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    Ok((code, headers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = read_request(&mut BufReader::new(&raw[..]), 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn rejects_oversized_body_and_garbage() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\n";
        assert!(read_request(&mut BufReader::new(&raw[..]), 10).is_err());
        let raw = b"not an http request\r\n\r\n";
        assert!(read_request(&mut BufReader::new(&raw[..]), 10).is_err());
    }

    #[test]
    fn extra_headers_are_emitted() {
        let mut out = Vec::new();
        write_response_with(
            &mut out,
            200,
            "text/plain",
            &[("X-Request-Id", "7".to_string())],
            b"ok",
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("X-Request-Id: 7\r\n"), "{text}");
        let mut sse = Vec::new();
        write_sse_head_with(&mut sse, &[("X-Request-Id", "9".to_string())]).unwrap();
        let text = String::from_utf8(sse).unwrap();
        assert!(text.contains("text/event-stream"), "{text}");
        assert!(text.contains("X-Request-Id: 9\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n"), "{text}");
    }

    #[test]
    fn response_roundtrip() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{\"error\":\"shed\"}").unwrap();
        let text = String::from_utf8(out.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.ends_with("{\"error\":\"shed\"}"));
        let mut r = BufReader::new(&out[..]);
        let (code, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(code, 429);
        assert!(headers.iter().any(|(k, v)| k == "Content-Length" && v == "16"));
    }
}
