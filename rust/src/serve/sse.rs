//! Server-Sent Events framing: writer-side frames and a client-side
//! incremental parser (used by the loopback harness and the e2e tests).
//!
//! Wire format per event: optional `event: <name>` line, one or more
//! `data: <payload>` lines, blank-line terminator. Unnamed frames carry
//! the default event name `message` (one per [`GenEvent::Token`]);
//! terminal frames are named `done` / `error`.
//!
//! [`GenEvent::Token`]: crate::coordinator::request::GenEvent

use anyhow::Result;
use std::io::BufRead;

/// A data-only frame (default `message` event).
pub fn data_frame(data: &str) -> String {
    format!("data: {data}\n\n")
}

/// A named event frame.
pub fn event_frame(name: &str, data: &str) -> String {
    format!("event: {name}\ndata: {data}\n\n")
}

/// One parsed client-side event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SseEvent {
    /// event name (`message` when the frame carried no `event:` line)
    pub event: String,
    pub data: String,
}

/// Read the next event from an SSE stream; `None` on clean end-of-stream.
/// Multi-line `data:` payloads are joined with `\n` per the SSE spec;
/// comment lines (leading `:`) are ignored.
pub fn read_event(r: &mut impl BufRead) -> Result<Option<SseEvent>> {
    let mut event = String::from("message");
    let mut data = String::new();
    let mut saw_data = false;
    loop {
        let mut line = String::new();
        let n = r.read_line(&mut line)?;
        if n == 0 {
            return Ok(saw_data.then_some(SseEvent { event, data }));
        }
        let line = line.trim_end_matches(['\r', '\n']);
        if line.is_empty() {
            if saw_data {
                return Ok(Some(SseEvent { event, data }));
            }
            continue;
        }
        if line.starts_with(':') {
            continue;
        }
        if let Some(v) = line.strip_prefix("event:") {
            event = v.trim_start().to_string();
        } else if let Some(v) = line.strip_prefix("data:") {
            if saw_data {
                data.push('\n');
            }
            data.push_str(v.strip_prefix(' ').unwrap_or(v));
            saw_data = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn frames_roundtrip() {
        let wire = format!(
            "{}{}{}",
            data_frame("{\"token\":7}"),
            event_frame("done", "{\"tokens\":[7]}"),
            ": keep-alive comment\n\n"
        );
        let mut r = BufReader::new(wire.as_bytes());
        let a = read_event(&mut r).unwrap().unwrap();
        assert_eq!(a.event, "message");
        assert_eq!(a.data, "{\"token\":7}");
        let b = read_event(&mut r).unwrap().unwrap();
        assert_eq!(b.event, "done");
        assert_eq!(b.data, "{\"tokens\":[7]}");
        assert!(read_event(&mut r).unwrap().is_none());
    }

    #[test]
    fn multiline_data_joined() {
        let mut r = BufReader::new("data: a\ndata: b\n\n".as_bytes());
        let ev = read_event(&mut r).unwrap().unwrap();
        assert_eq!(ev.data, "a\nb");
    }
}
