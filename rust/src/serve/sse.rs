//! Server-Sent Events framing: writer-side frames and a client-side
//! incremental parser (used by the loopback harness and the e2e tests).
//!
//! Wire format per event: optional `event: <name>` line, one or more
//! `data: <payload>` lines, blank-line terminator. Unnamed frames carry
//! the default event name `message` (one per [`GenEvent::Token`]);
//! terminal frames are named `done` / `error`.
//!
//! Parsing is incremental: [`SseParser`] buffers raw bytes and only
//! dispatches at the blank-line frame delimiter, so a frame split across
//! read boundaries at any byte offset — or several frames coalesced into
//! one read — parses identically to tidy one-frame-per-read delivery.
//!
//! [`GenEvent::Token`]: crate::coordinator::request::GenEvent

use anyhow::Result;
use std::io::{BufRead, Read};

/// A data-only frame (default `message` event).
pub fn data_frame(data: &str) -> String {
    format!("data: {data}\n\n")
}

/// A named event frame.
pub fn event_frame(name: &str, data: &str) -> String {
    format!("event: {name}\ndata: {data}\n\n")
}

/// One parsed client-side event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SseEvent {
    /// event name (`message` when the frame carried no `event:` line)
    pub event: String,
    pub data: String,
}

/// Incremental SSE parser. [`feed`](SseParser::feed) arbitrary byte
/// chunks, [`next_event`](SseParser::next_event) complete frames out;
/// [`finish`](SseParser::finish) flushes a trailing unterminated frame at
/// end-of-stream. Frame boundaries are the blank-line delimiter, never
/// the read boundary, so chunking cannot change what parses.
///
/// Multi-line `data:` payloads are joined with `\n` per the SSE spec;
/// comment lines (leading `:`) are ignored.
#[derive(Debug)]
pub struct SseParser {
    buf: Vec<u8>,
    event: String,
    data: String,
    saw_data: bool,
}

impl SseParser {
    pub fn new() -> SseParser {
        SseParser {
            buf: Vec::new(),
            event: String::from("message"),
            data: String::new(),
            saw_data: false,
        }
    }

    /// Append one received chunk (any length, any alignment).
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Take the next complete frame, if its blank-line delimiter has
    /// arrived. Returns `None` when the buffered tail is still mid-frame.
    pub fn next_event(&mut self) -> Option<SseEvent> {
        while let Some(line) = self.take_line() {
            if let Some(ev) = self.accept_line(&line) {
                return Some(ev);
            }
        }
        None
    }

    /// End-of-stream flush: parses any unterminated trailing line and
    /// dispatches a final frame that never got its blank-line delimiter.
    pub fn finish(&mut self) -> Option<SseEvent> {
        if !self.buf.is_empty() {
            let rest = std::mem::take(&mut self.buf);
            let line = String::from_utf8_lossy(&rest).into_owned();
            if let Some(ev) = self.accept_line(line.trim_end_matches(['\r', '\n'])) {
                return Some(ev);
            }
        }
        self.saw_data.then(|| self.dispatch())
    }

    /// Pop one complete line (through its `\n`) off the buffer front.
    fn take_line(&mut self) -> Option<String> {
        let nl = self.buf.iter().position(|&b| b == b'\n')?;
        let raw: Vec<u8> = self.buf.drain(..=nl).collect();
        let line = String::from_utf8_lossy(&raw).into_owned();
        Some(line.trim_end_matches(['\r', '\n']).to_string())
    }

    /// Fold one line into the in-progress frame; a dispatching blank
    /// line yields the frame.
    fn accept_line(&mut self, line: &str) -> Option<SseEvent> {
        if line.is_empty() {
            return self.saw_data.then(|| self.dispatch());
        }
        if line.starts_with(':') {
            return None;
        }
        if let Some(v) = line.strip_prefix("event:") {
            self.event = v.trim_start().to_string();
        } else if let Some(v) = line.strip_prefix("data:") {
            if self.saw_data {
                self.data.push('\n');
            }
            self.data.push_str(v.strip_prefix(' ').unwrap_or(v));
            self.saw_data = true;
        }
        None
    }

    fn dispatch(&mut self) -> SseEvent {
        self.saw_data = false;
        SseEvent {
            event: std::mem::replace(&mut self.event, String::from("message")),
            data: std::mem::take(&mut self.data),
        }
    }
}

impl Default for SseParser {
    fn default() -> SseParser {
        SseParser::new()
    }
}

/// Pump bytes from `r` into `p` until one complete frame is available;
/// `None` on clean end-of-stream (after flushing any trailing frame).
/// Reads are chunk-oriented, so frames straddling read boundaries or
/// coalesced into one read parse identically.
pub fn next_from(r: &mut impl Read, p: &mut SseParser) -> Result<Option<SseEvent>> {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(ev) = p.next_event() {
            return Ok(Some(ev));
        }
        let n = r.read(&mut chunk)?;
        if n == 0 {
            return Ok(p.finish());
        }
        p.feed(&chunk[..n]);
    }
}

/// Read the next event from an SSE stream; `None` on clean end-of-stream.
/// Line-at-a-time convenience over [`SseParser`] for `BufRead` call sites
/// (leaves bytes past the frame in the reader).
pub fn read_event(r: &mut impl BufRead) -> Result<Option<SseEvent>> {
    let mut p = SseParser::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            return Ok(p.finish());
        }
        p.feed(line.as_bytes());
        if let Some(ev) = p.next_event() {
            return Ok(Some(ev));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn frames_roundtrip() {
        let wire = format!(
            "{}{}{}",
            data_frame("{\"token\":7}"),
            event_frame("done", "{\"tokens\":[7]}"),
            ": keep-alive comment\n\n"
        );
        let mut r = BufReader::new(wire.as_bytes());
        let a = read_event(&mut r).unwrap().unwrap();
        assert_eq!(a.event, "message");
        assert_eq!(a.data, "{\"token\":7}");
        let b = read_event(&mut r).unwrap().unwrap();
        assert_eq!(b.event, "done");
        assert_eq!(b.data, "{\"tokens\":[7]}");
        assert!(read_event(&mut r).unwrap().is_none());
    }

    #[test]
    fn multiline_data_joined() {
        let mut r = BufReader::new("data: a\ndata: b\n\n".as_bytes());
        let ev = read_event(&mut r).unwrap().unwrap();
        assert_eq!(ev.data, "a\nb");
    }

    fn expected_stream() -> (String, Vec<SseEvent>) {
        let wire = format!(
            "{}{}{}{}",
            data_frame("{\"token\":1}"),
            ": keep-alive\n\n",
            event_frame("message", "{\"token\":2}"),
            event_frame("done", "{\"tokens\":[1,2]}"),
        );
        let expect = vec![
            SseEvent { event: "message".into(), data: "{\"token\":1}".into() },
            SseEvent { event: "message".into(), data: "{\"token\":2}".into() },
            SseEvent { event: "done".into(), data: "{\"tokens\":[1,2]}".into() },
        ];
        (wire, expect)
    }

    fn drain(p: &mut SseParser, into: &mut Vec<SseEvent>) {
        while let Some(ev) = p.next_event() {
            into.push(ev);
        }
    }

    #[test]
    fn parses_identically_when_split_at_every_byte_offset() {
        // The documented straddle bug: a frame cut anywhere by a read
        // boundary (or two frames coalesced into one read — cut = 0 and
        // cut = len cover both extremes) must parse exactly like tidy
        // one-frame-per-read delivery.
        let (wire, expect) = expected_stream();
        for cut in 0..=wire.len() {
            let (a, b) = wire.as_bytes().split_at(cut);
            let mut p = SseParser::new();
            let mut got = Vec::new();
            p.feed(a);
            drain(&mut p, &mut got);
            p.feed(b);
            drain(&mut p, &mut got);
            if let Some(ev) = p.finish() {
                got.push(ev);
            }
            assert_eq!(got, expect, "split at byte {cut}");
        }
    }

    #[test]
    fn parses_one_byte_at_a_time() {
        let (wire, expect) = expected_stream();
        let mut p = SseParser::new();
        let mut got = Vec::new();
        for &b in wire.as_bytes() {
            p.feed(&[b]);
            drain(&mut p, &mut got);
        }
        if let Some(ev) = p.finish() {
            got.push(ev);
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn finish_flushes_a_frame_missing_its_terminator() {
        let mut p = SseParser::new();
        p.feed(b"event: done\ndata: {\"tokens\":[]}");
        assert!(p.next_event().is_none(), "no delimiter yet");
        let ev = p.finish().expect("EOF must flush the trailing frame");
        assert_eq!(ev.event, "done");
        assert_eq!(ev.data, "{\"tokens\":[]}");
        assert!(p.finish().is_none(), "finish must not dispatch twice");
    }

    /// A reader that returns one byte per `read` call: the worst-case
    /// chunking a TCP stream can legally produce.
    struct Trickle<'a>(&'a [u8]);

    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            match self.0.split_first() {
                Some((&b, rest)) => {
                    buf[0] = b;
                    self.0 = rest;
                    Ok(1)
                }
                None => Ok(0),
            }
        }
    }

    #[test]
    fn next_from_survives_single_byte_reads() {
        let (wire, expect) = expected_stream();
        let mut r = Trickle(wire.as_bytes());
        let mut p = SseParser::new();
        let mut got = Vec::new();
        while let Some(ev) = next_from(&mut r, &mut p).unwrap() {
            got.push(ev);
        }
        assert_eq!(got, expect);
    }
}
