//! Sequence scoring abstraction over the two execution paths.
//!
//! Everything in the eval harness reduces to "give me the logits of this
//! token sequence": perplexity, multiple-choice likelihoods and the judge
//! all go through [`Scorer`].

use crate::coordinator::backend;
use crate::engine::native::EngineWs;
use crate::engine::{NativeEngine, SubMode};
use crate::model::{Config, WeightStore};
use crate::runtime::exec::{build_weight_feed, Value};
use crate::runtime::{ExecRegistry, LoadedExec, Manifest};
use crate::tensor::ops;
use anyhow::{bail, Result};
use std::sync::Arc;

pub trait Scorer {
    fn cfg(&self) -> &Config;

    /// Full-sequence logits: `tokens [T]` → flat `[T * vocab]`.
    fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>>;

    /// Sum log-likelihood of `tokens[from+1 ..]` given the prefix.
    fn sum_ll(&mut self, tokens: &[u32], from: usize) -> Result<f64> {
        let v = self.cfg().vocab;
        let logits = self.logits(&tokens[..tokens.len() - 1])?;
        let mut total = 0f64;
        for t in from..tokens.len() - 1 {
            let row = &logits[t * v..(t + 1) * v];
            total += ops::log_softmax_at(row, tokens[t + 1] as usize) as f64;
        }
        Ok(total)
    }
}

/// Native-engine scorer.
pub struct NativeScorer {
    engine: NativeEngine,
    ws: EngineWs,
}

impl NativeScorer {
    pub fn new(engine: NativeEngine) -> NativeScorer {
        NativeScorer { engine, ws: EngineWs::default() }
    }

    pub fn from_checkpoint(path: &std::path::Path, mode: SubMode) -> Result<NativeScorer> {
        let store = WeightStore::load(path)?;
        Ok(NativeScorer::new(NativeEngine::from_store(&store, mode)?))
    }

    pub fn engine(&self) -> &NativeEngine {
        &self.engine
    }
}

impl Scorer for NativeScorer {
    fn cfg(&self) -> &Config {
        &self.engine.cfg
    }

    fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        if tokens.len() > self.engine.cfg.max_seq {
            bail!("sequence of {} exceeds max_seq {}", tokens.len(), self.engine.cfg.max_seq);
        }
        Ok(self.engine.forward_full(tokens, &mut self.ws))
    }
}

/// PJRT scorer over a `score_<model>_{fp,q}` artifact.
///
/// The artifact has a fixed `[B, T]` shape; shorter sequences are
/// right-padded (causality makes the padded tail irrelevant to the
/// positions we read) and only slot 0 is consumed.
pub struct PjrtScorer {
    exec: Arc<LoadedExec>,
    weights: Arc<Vec<xla::Literal>>,
    cfg: Config,
    batch: usize,
    seq: usize,
}

impl PjrtScorer {
    pub fn new(registry: &mut ExecRegistry, store: &WeightStore) -> Result<PjrtScorer> {
        let name = Manifest::score_name(&store.cfg.name, store.is_quantized());
        let exec = registry.load(&name)?;
        let weights = Arc::new(build_weight_feed(&exec.spec, store)?);
        Ok(PjrtScorer {
            cfg: store.cfg.clone(),
            batch: exec.spec.batch,
            seq: exec.spec.seq,
            exec,
            weights,
        })
    }

    /// Score up to `batch` sequences in one dispatch (the batched path the
    /// Table-1 bench uses). Each entry gets its own `[T*vocab]` logits,
    /// truncated to its true length.
    pub fn logits_batch(&mut self, seqs: &[&[u32]]) -> Result<Vec<Vec<f32>>> {
        if seqs.is_empty() || seqs.len() > self.batch {
            bail!("batch of {} vs compiled {}", seqs.len(), self.batch);
        }
        let v = self.cfg.vocab;
        let mut toks = vec![1i32; self.batch * self.seq];
        for (i, s) in seqs.iter().enumerate() {
            if s.len() > self.seq {
                bail!("sequence of {} exceeds compiled seq {}", s.len(), self.seq);
            }
            for (j, &t) in s.iter().enumerate() {
                toks[i * self.seq + j] = t as i32;
            }
        }
        let out = self.exec.run(&[Value::I32(toks)], &self.weights)?;
        let flat = out[0].as_f32()?;
        Ok(seqs
            .iter()
            .enumerate()
            .map(|(i, s)| flat[i * self.seq * v..i * self.seq * v + s.len() * v].to_vec())
            .collect())
    }

    fn logits_impl(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        Ok(self.logits_batch(&[tokens])?.remove(0))
    }
}

impl Scorer for PjrtScorer {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        self.logits_impl(tokens)
    }
}

/// Generation-path scorer used to cross-check the serve artifacts: builds
/// logits via a backend's slot prefill (slower; tests only).
pub fn backend_last_logits(b: &mut dyn backend::Backend, tokens: &[u32]) -> Result<Vec<f32>> {
    let mut state = b.open_batch(1)?;
    let logits = b.prefill_slot(&mut state, 0, tokens)?;
    b.release_slot(&mut state, 0)?;
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct FakeScorer {
        cfg: Config,
    }

    impl Scorer for FakeScorer {
        fn cfg(&self) -> &Config {
            &self.cfg
        }

        fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
            // deterministic: logit = 1.0 on (next == current + 1 mod V)
            let v = self.cfg.vocab;
            let mut out = vec![0f32; tokens.len() * v];
            for (t, &tok) in tokens.iter().enumerate() {
                out[t * v + ((tok as usize + 1) % v)] = 5.0;
            }
            Ok(out)
        }
    }

    fn fake() -> FakeScorer {
        let j = crate::util::json::Json::parse(
            r#"{"name":"f","family":"llamoid","d_model":8,"n_layers":1,
                "n_heads":2,"d_ff":8,"vocab":16,"max_seq":64}"#,
        )
        .unwrap();
        FakeScorer { cfg: Config::from_json(&j).unwrap() }
    }

    #[test]
    fn sum_ll_prefers_predictable_sequences() {
        let mut s = fake();
        let good: Vec<u32> = (0..10).collect(); // follows the +1 rule
        let bad: Vec<u32> = vec![0, 5, 3, 9, 1, 2, 8, 4, 7, 6];
        let lg = s.sum_ll(&good, 0).unwrap();
        let lb = s.sum_ll(&bad, 0).unwrap();
        assert!(lg > lb);
    }

    #[test]
    fn sum_ll_from_skips_prefix() {
        let mut s = fake();
        let toks: Vec<u32> = (0..10).collect();
        let full = s.sum_ll(&toks, 0).unwrap();
        let tail = s.sum_ll(&toks, 5).unwrap();
        assert!(full < tail); // fewer (negative) terms
    }
}
