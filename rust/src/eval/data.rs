//! Loaders for the evaluation archives written by `python/compile/corpus.py`.

use crate::quant::formats::Archive;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A flat byte-token stream (corpus splits).
#[derive(Debug, Clone)]
pub struct TokenStream {
    tokens: Vec<u8>,
}

impl TokenStream {
    pub fn load(path: &Path) -> Result<TokenStream> {
        let arc = Archive::load(path)?;
        Ok(TokenStream { tokens: arc.get("tokens")?.as_u8()?.to_vec() })
    }

    pub fn from_vec(tokens: Vec<u8>) -> TokenStream {
        TokenStream { tokens }
    }

    pub fn tokens(&self) -> &[u8] {
        &self.tokens
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Non-overlapping windows of `seq + 1` tokens (scoring needs the
    /// shifted target), as u32 ids.
    pub fn windows(&self, seq: usize, max_windows: usize) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + seq + 1 <= self.tokens.len() && out.len() < max_windows {
            out.push(self.tokens[i..i + seq + 1].iter().map(|&b| b as u32).collect());
            i += seq;
        }
        out
    }
}

/// One multiple-choice question.
#[derive(Debug, Clone)]
pub struct McQuestion {
    pub context: Vec<u32>,
    pub options: Vec<Vec<u32>>,
    pub correct: usize,
}

/// A multiple-choice suite (one of the seven zero-shot tasks).
#[derive(Debug, Clone)]
pub struct McTask {
    pub name: String,
    pub n_options: usize,
    pub questions: Vec<McQuestion>,
}

fn offsets_split(flat: &[u8], off: &[u32]) -> Vec<Vec<u32>> {
    off.windows(2)
        .map(|w| flat[w[0] as usize..w[1] as usize].iter().map(|&b| b as u32).collect())
        .collect()
}

impl McTask {
    pub fn load(path: &Path) -> Result<McTask> {
        let arc = Archive::load(path)?;
        let name = arc.meta_str("task").unwrap_or("?").to_string();
        let n_options = arc.meta_usize("n_options").context("n_options")?;
        let nq = arc.meta_usize("n_questions").context("n_questions")?;
        let ctxs = offsets_split(arc.get("ctx_flat")?.as_u8()?, &arc.get("ctx_off")?.as_u32()?);
        let opts = offsets_split(arc.get("opt_flat")?.as_u8()?, &arc.get("opt_off")?.as_u32()?);
        let correct = arc.get("correct")?.as_u32()?;
        if ctxs.len() != nq || opts.len() != nq * n_options || correct.len() != nq {
            bail!("{}: inconsistent task archive", path.display());
        }
        let questions = (0..nq)
            .map(|i| McQuestion {
                context: ctxs[i].clone(),
                options: opts[i * n_options..(i + 1) * n_options].to_vec(),
                correct: correct[i] as usize,
            })
            .collect();
        Ok(McTask { name, n_options, questions })
    }

    /// Load all task archives under `artifacts/data/tasks/`.
    pub fn load_all(data_dir: &Path) -> Result<Vec<McTask>> {
        let dir = data_dir.join("tasks");
        let mut names: Vec<_> = std::fs::read_dir(&dir)
            .with_context(|| format!("reading {}", dir.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "fbqw"))
            .collect();
        names.sort();
        names.iter().map(|p| McTask::load(p)).collect()
    }
}

/// The Fig-6 judge set: prompts with gold continuations.
#[derive(Debug, Clone)]
pub struct JudgeSet {
    pub contexts: Vec<Vec<u32>>,
    pub golds: Vec<Vec<u32>>,
}

impl JudgeSet {
    pub fn load(path: &Path) -> Result<JudgeSet> {
        let arc = Archive::load(path)?;
        let contexts = offsets_split(arc.get("ctx_flat")?.as_u8()?, &arc.get("ctx_off")?.as_u32()?);
        let golds = offsets_split(arc.get("gold_flat")?.as_u8()?, &arc.get("gold_off")?.as_u32()?);
        if contexts.len() != golds.len() {
            bail!("judge set: context/gold count mismatch");
        }
        Ok(JudgeSet { contexts, golds })
    }

    pub fn len(&self) -> usize {
        self.contexts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.contexts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_stream() {
        let s = TokenStream::from_vec((0..100u32).map(|i| i as u8).collect());
        let w = s.windows(10, 100);
        assert_eq!(w.len(), 9); // 9 windows of 11 tokens, stride 10
        assert_eq!(w[0].len(), 11);
        assert_eq!(w[1][0], 10);
        let capped = s.windows(10, 3);
        assert_eq!(capped.len(), 3);
    }

    #[test]
    fn offsets_split_basic() {
        let flat = [10u8, 11, 12, 13, 14];
        let off = [0u32, 2, 5];
        let parts = offsets_split(&flat, &off);
        assert_eq!(parts, vec![vec![10u32, 11], vec![12, 13, 14]]);
    }
}
