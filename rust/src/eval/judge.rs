//! Pairwise model comparison (Fig 6's protocol).
//!
//! The paper judges two quantized instruction-tuned models with GPT-4 on
//! 80 Vicuna questions, testing both answer orders (160 trials) to cancel
//! position bias. Our deterministic judge compares per-question held-out
//! loss: model A "wins" a trial when its gold-continuation likelihood
//! beats B's by more than a tie margin. Both "orders" are evaluated with
//! the margin applied to either side, mirroring the 2×80-trial protocol.

use super::data::JudgeSet;
use super::scorer::Scorer;
use anyhow::Result;

#[derive(Debug, Clone, Copy, Default)]
pub struct PairwiseResult {
    pub wins: usize,
    pub ties: usize,
    pub losses: usize,
}

impl PairwiseResult {
    pub fn trials(&self) -> usize {
        self.wins + self.ties + self.losses
    }

    pub fn win_pct(&self) -> f64 {
        100.0 * self.wins as f64 / self.trials().max(1) as f64
    }

    pub fn tie_pct(&self) -> f64 {
        100.0 * self.ties as f64 / self.trials().max(1) as f64
    }

    pub fn loss_pct(&self) -> f64 {
        100.0 * self.losses as f64 / self.trials().max(1) as f64
    }

    pub fn win_tie_pct(&self) -> f64 {
        self.win_pct() + self.tie_pct()
    }
}

/// Per-question, per-token gold NLLs for one model.
pub fn question_nlls(scorer: &mut dyn Scorer, set: &JudgeSet) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(set.len());
    for (ctx, gold) in set.contexts.iter().zip(&set.golds) {
        let mut seq = ctx.clone();
        seq.extend_from_slice(gold);
        let max = scorer.cfg().max_seq;
        if seq.len() > max {
            // keep the gold fully; trim oldest context
            seq.drain(..seq.len() - max);
        }
        let from = seq.len() - gold.len() - 1;
        let ll = scorer.sum_ll(&seq, from)?;
        out.push(-ll / gold.len() as f64);
    }
    Ok(out)
}

/// Compare two models' per-question NLLs with the 2-order protocol.
///
/// `margin` is the relative tie band (fraction of the mean NLL).
pub fn compare(nll_a: &[f64], nll_b: &[f64], margin: f64) -> PairwiseResult {
    assert_eq!(nll_a.len(), nll_b.len());
    let mut r = PairwiseResult::default();
    for (&a, &b) in nll_a.iter().zip(nll_b) {
        let band = margin * 0.5 * (a + b);
        // order 1: A presented first
        if a < b - band {
            r.wins += 1;
        } else if b < a - band {
            r.losses += 1;
        } else {
            r.ties += 1;
        }
        // order 2: B presented first (symmetric margin; deterministic
        // judge has no position bias, so this doubles the trial count as
        // in the paper's 160-trial protocol)
        if b < a - band {
            r.losses += 1;
        } else if a < b - band {
            r.wins += 1;
        } else {
            r.ties += 1;
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clear_winner() {
        let a = vec![1.0, 1.0, 1.0];
        let b = vec![2.0, 2.0, 2.0];
        let r = compare(&a, &b, 0.05);
        assert_eq!(r.wins, 6);
        assert_eq!(r.losses, 0);
        assert_eq!(r.trials(), 6);
        assert!((r.win_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn near_equal_is_tie() {
        let a = vec![1.00, 2.00];
        let b = vec![1.01, 1.99];
        let r = compare(&a, &b, 0.10);
        assert_eq!(r.ties, 4);
    }

    #[test]
    fn mixed_results() {
        let a = vec![1.0, 3.0];
        let b = vec![2.0, 1.0];
        let r = compare(&a, &b, 0.01);
        assert_eq!(r.wins, 2);
        assert_eq!(r.losses, 2);
    }
}
