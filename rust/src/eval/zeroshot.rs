//! Zero-shot multiple-choice evaluation (Tables 2–8's metric).
//!
//! lm-eval-harness scoring: for each option, compute the log-likelihood of
//! the option tokens given the context, normalised by option length; the
//! argmax option is the prediction.

use super::data::McTask;
use super::scorer::Scorer;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: String,
    pub n: usize,
    pub correct: usize,
}

impl TaskResult {
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.correct as f64 / self.n as f64
        }
    }
}

/// Evaluate one task. `max_questions` truncates for fast subset runs.
pub fn eval_task(
    scorer: &mut dyn Scorer,
    task: &McTask,
    max_questions: usize,
) -> Result<TaskResult> {
    let mut correct = 0usize;
    let n = task.questions.len().min(max_questions);
    for q in task.questions.iter().take(n) {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (oi, opt) in q.options.iter().enumerate() {
            let mut seq = q.context.clone();
            seq.extend_from_slice(opt);
            // score the option tokens only, length-normalised.
            // `from` is the index of the last context token (likelihood of
            // tokens from+1.. = the option tokens given the context).
            let from = q.context.len().saturating_sub(1);
            let ll = scorer.sum_ll(&seq, from)?;
            let norm = ll / opt.len().max(1) as f64;
            if norm > best.0 {
                best = (norm, oi);
            }
        }
        if best.1 == q.correct {
            correct += 1;
        }
    }
    Ok(TaskResult { task: task.name.clone(), n, correct })
}

/// Evaluate a full suite; returns per-task results plus the macro average.
pub fn eval_suite(scorer: &mut dyn Scorer, tasks: &[McTask], max_questions: usize)
                  -> Result<(Vec<TaskResult>, f64)> {
    let mut results = Vec::with_capacity(tasks.len());
    for t in tasks {
        results.push(eval_task(scorer, t, max_questions)?);
    }
    let avg = if results.is_empty() {
        0.0
    } else {
        results.iter().map(|r| r.accuracy()).sum::<f64>() / results.len() as f64
    };
    Ok((results, avg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::data::McQuestion;
    use crate::model::Config;

    /// Scorer that loves ascending sequences (tok+1 rule).
    struct AscScorer {
        cfg: Config,
    }

    impl Scorer for AscScorer {
        fn cfg(&self) -> &Config {
            &self.cfg
        }

        fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
            let v = self.cfg.vocab;
            let mut out = vec![0f32; tokens.len() * v];
            for (t, &tok) in tokens.iter().enumerate() {
                out[t * v + ((tok as usize + 1) % v)] = 8.0;
            }
            Ok(out)
        }
    }

    #[test]
    fn picks_the_likely_option() {
        let j = crate::util::json::Json::parse(
            r#"{"name":"a","family":"llamoid","d_model":8,"n_layers":1,
                "n_heads":2,"d_ff":8,"vocab":16,"max_seq":64}"#,
        )
        .unwrap();
        let mut s = AscScorer { cfg: Config::from_json(&j).unwrap() };
        let task = McTask {
            name: "asc".into(),
            n_options: 2,
            questions: vec![
                McQuestion {
                    context: vec![1, 2, 3],
                    options: vec![vec![4, 5], vec![9, 9]],
                    correct: 0,
                },
                McQuestion {
                    context: vec![7, 8],
                    options: vec![vec![2, 2], vec![9, 10]],
                    correct: 1,
                },
            ],
        };
        let r = eval_task(&mut s, &task, 100).unwrap();
        assert_eq!(r.correct, 2);
        assert_eq!(r.accuracy(), 1.0);
    }

    #[test]
    fn max_questions_truncates() {
        let j = crate::util::json::Json::parse(
            r#"{"name":"a","family":"llamoid","d_model":8,"n_layers":1,
                "n_heads":2,"d_ff":8,"vocab":16,"max_seq":64}"#,
        )
        .unwrap();
        let mut s = AscScorer { cfg: Config::from_json(&j).unwrap() };
        let q = McQuestion { context: vec![1], options: vec![vec![2], vec![5]], correct: 0 };
        let task =
            McTask { name: "t".into(), n_options: 2, questions: vec![q.clone(), q.clone(), q] };
        let r = eval_task(&mut s, &task, 2).unwrap();
        assert_eq!(r.n, 2);
    }
}
