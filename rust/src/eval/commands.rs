//! `fbquant` CLI command implementations.

use super::data::{JudgeSet, McTask, TokenStream};
use super::ppl::{perplexity, PplConfig};
use super::scorer::{NativeScorer, PjrtScorer, Scorer};
use super::zeroshot::eval_suite;
use crate::coordinator::backend::{Backend, NativeBackend, PjrtBackend};
use crate::coordinator::overload::DegradeConfig;
use crate::coordinator::request::N_CLASSES;
use crate::coordinator::server::{Coordinator, CoordinatorConfig, CoordinatorHandle};
use crate::coordinator::workload::{self, Arrival, Workload, WorkloadConfig};
use crate::engine::{NativeEngine, SubMode};
use crate::model::{ByteTokenizer, WeightStore};
use crate::runtime::ExecRegistry;
use crate::serve::{self, harness, ServeConfig};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::signal;
use anyhow::{bail, ensure, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn artifacts() -> PathBuf {
    crate::artifacts_dir()
}

pub fn parse_submode(args: &Args) -> SubMode {
    if args.flag("no-sub") {
        SubMode::None
    } else if args.flag("fused") || args.get("submode") == Some("fused") {
        SubMode::Fused
    } else {
        match args.get("submode") {
            Some("none") => SubMode::None,
            Some("unfused") => SubMode::Unfused,
            _ => SubMode::Fused,
        }
    }
}

pub fn load_store(args: &Args) -> Result<WeightStore> {
    let model = args.get("model").unwrap_or("llamoid-tiny");
    let method = args.get("method").unwrap_or("fp");
    let bits = args.get_usize("bits", 4)? as u8;
    let path = WeightStore::path_for(&artifacts(), model, method, bits);
    WeightStore::load(&path)
        .with_context(|| format!("loading checkpoint {} (run `make artifacts`)", path.display()))
}

fn make_scorer(args: &Args, store: &WeightStore) -> Result<Box<dyn Scorer>> {
    match args.get_or("backend", "native") {
        "native" => {
            let engine = NativeEngine::from_store(store, parse_submode(args))?;
            Ok(Box::new(NativeScorer::new(engine)))
        }
        "pjrt" => {
            let mut reg = ExecRegistry::open(&artifacts())?;
            Ok(Box::new(PjrtScorer::new(&mut reg, store)?))
        }
        other => bail!("unknown backend '{other}' (native|pjrt)"),
    }
}

pub fn cmd_info(_args: &Args) -> Result<()> {
    let root = artifacts();
    println!("artifact root: {}", root.display());
    let models_dir = root.join("models");
    if let Ok(dir) = std::fs::read_dir(&models_dir) {
        let mut names: Vec<_> = dir
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".fbqw"))
            .collect();
        names.sort();
        println!("checkpoints ({}):", names.len());
        for n in &names {
            if let Ok(store) = WeightStore::load(&models_dir.join(n)) {
                println!(
                    "  {n:44} {:>8} params={:.2}M bytes={}",
                    store.method,
                    store.cfg.n_params() as f64 / 1e6,
                    crate::util::human_bytes(store.resident_bytes()),
                );
            }
        }
    } else {
        println!("no checkpoints (run `make artifacts`)");
    }
    match crate::runtime::Manifest::load(&root) {
        Ok(m) => {
            println!("HLO artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                let n_inputs = a.inputs.len();
                println!("  {:40} kind={} batch={} inputs={n_inputs}", a.name, a.kind, a.batch);
            }
        }
        Err(_) => println!("no HLO manifest (run `make artifacts`)"),
    }
    Ok(())
}

pub fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let store = load_store(args)?;
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let cfg = PplConfig {
        seq: args.get_usize("seq", 128)?,
        max_tokens: args.get_usize("max-tokens", 16_384)?,
    };
    let mut scorer = make_scorer(args, &store)?;
    let t0 = std::time::Instant::now();
    let r = perplexity(scorer.as_mut(), &stream, cfg)?;
    println!(
        "model={} method={} bits={} ppl={:.4} nll/tok={:.4} tokens={} ({:.1}s)",
        store.cfg.name,
        store.method,
        store.bits,
        r.ppl,
        r.nll_per_token,
        r.tokens,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

pub fn cmd_eval_zeroshot(args: &Args) -> Result<()> {
    let store = load_store(args)?;
    let tasks = McTask::load_all(&artifacts().join("data"))?;
    let maxq = args.get_usize("max-questions", 80)?;
    let mut scorer = make_scorer(args, &store)?;
    let (results, avg) = eval_suite(scorer.as_mut(), &tasks, maxq)?;
    println!("model={} method={} bits={}", store.cfg.name, store.method, store.bits);
    for r in &results {
        println!("  {:10} acc={:.2}% ({}/{})", r.task, 100.0 * r.accuracy(), r.correct, r.n);
    }
    println!("  {:10} avg={:.2}%", "AVG", 100.0 * avg);
    Ok(())
}

pub fn cmd_judge(args: &Args) -> Result<()> {
    let set = JudgeSet::load(&artifacts().join("data/judge.fbqw"))?;
    let model = args.get("model").unwrap_or("llamoid-tiny");
    let bits = args.get_usize("bits", 3)? as u8;
    let method_a = args.get("method").unwrap_or("fbquant");
    let method_b = args.get("against").unwrap_or("awq");
    let margin = args.get_f64("margin", 0.02)?;

    let mut nlls = Vec::new();
    for method in [method_a, method_b] {
        let store = WeightStore::load(&WeightStore::path_for(&artifacts(), model, method, bits))?;
        let mut scorer = make_scorer(args, &store)?;
        nlls.push(super::judge::question_nlls(scorer.as_mut(), &set)?);
    }
    let r = super::judge::compare(&nlls[0], &nlls[1], margin);
    println!(
        "{model} w{bits}: {method_a} vs {method_b}: \
         win {:.1}% / tie {:.1}% / loss {:.1}% ({} trials)",
        r.win_pct(),
        r.tie_pct(),
        r.loss_pct(),
        r.trials()
    );
    Ok(())
}

pub fn cmd_generate(args: &Args) -> Result<()> {
    let store = load_store(args)?;
    let tok = ByteTokenizer::default();
    let prompt_text = args.get("prompt").unwrap_or("= sea =\nthe salty crab ");
    let prompt = tok.encode(prompt_text);
    let n = args.get_usize("tokens", 48)?;

    let mut backend: Box<dyn Backend> = match args.get_or("backend", "native") {
        "native" => Box::new(NativeBackend::new(
            NativeEngine::from_store(&store, parse_submode(args))?,
            &store.cfg.name,
        )),
        "pjrt" => {
            let mut reg = ExecRegistry::open(&artifacts())?;
            Box::new(PjrtBackend::new(&mut reg, &store, &[1], &store.cfg.name)?)
        }
        other => bail!("unknown backend '{other}'"),
    };

    use crate::coordinator::request::GenRequest;
    let mut req = GenRequest::new(1, prompt, n);
    req.params.temperature = args.get_f64("temperature", 0.0)? as f32;
    let (responses, metrics) =
        Coordinator::run_closed_loop(backend.as_mut(), vec![req], &CoordinatorConfig::default())?;
    let r = &responses[0];
    println!("{}{}", prompt_text, tok.decode(&r.tokens));
    println!(
        "\n[{} tokens, ttft={:.1}ms, {:.1} tk/s decode, backend={}]",
        r.tokens.len(),
        r.ttft_us / 1e3,
        r.decode_tps(),
        backend.name()
    );
    let _ = metrics;
    Ok(())
}

/// Spawn the coordinator worker selected by the CLI args and return the
/// handle plus the model context length (used to clamp workloads).
/// `--synth` serves a synthesized checkpoint — no `make artifacts`
/// needed, which is what the CI serve-smoke job runs on.
fn spawn_coordinator(args: &Args) -> Result<(CoordinatorHandle, usize)> {
    // --sync forces the batch-synchronous aligned-group baseline; pjrt
    // runs per-lane surfaces when continuous (the lock-step artifacts
    // cannot admit mid-flight)
    let continuous = !args.flag("sync");
    let mut cfg = CoordinatorConfig { continuous, ..CoordinatorConfig::default() };
    if args.flag("degrade") {
        // load-adaptive degradation (spec-K cap / bare branch / shadow
        // engine) — off unless asked for, thresholds at their defaults
        cfg.degrade = DegradeConfig { enabled: true, ..DegradeConfig::default() };
    }
    // --pages shrinks the target KV pool (overload / preemption
    // experiments); 0 keeps the backend's own sizing
    let pages = args.get_usize("pages", 0)?;
    let page_size = args.get_usize("page-size", 16)?;
    let submode = parse_submode(args);
    if args.flag("synth") {
        let spec = crate::testing::SynthSpec {
            vocab: 96,
            max_seq: 256,
            ..crate::testing::SynthSpec::default()
        };
        let store = crate::testing::synth_checkpoint("serve_synth", spec);
        let max_seq = store.cfg.max_seq;
        let handle = Coordinator::spawn(
            move || -> Result<Box<dyn Backend>> {
                let mut be = NativeBackend::new(
                    NativeEngine::from_store(&store, submode)?,
                    "serve-synth",
                );
                if pages > 0 {
                    be = be.with_kv_pool(page_size, pages);
                }
                Ok(Box::new(be))
            },
            cfg,
        );
        return Ok((handle, max_seq));
    }
    let store = load_store(args)?;
    let max_seq = store.cfg.max_seq;
    let backend_kind = args.get_or("backend", "native").to_string();
    let art = artifacts();
    let handle = Coordinator::spawn(
        move || -> Result<Box<dyn Backend>> {
            Ok(match backend_kind.as_str() {
                "pjrt" => {
                    let mut reg = ExecRegistry::open(&art)?;
                    Box::new(
                        PjrtBackend::new(&mut reg, &store, &[1, 4], &store.cfg.name)?
                            .with_per_lane(continuous),
                    )
                }
                _ => {
                    let mut be = NativeBackend::new(
                        NativeEngine::from_store(&store, submode)?,
                        &store.cfg.name,
                    );
                    if pages > 0 {
                        be = be.with_kv_pool(page_size, pages);
                    }
                    Box::new(be)
                }
            })
        },
        cfg,
    );
    Ok((handle, max_seq))
}

pub fn cmd_serve(args: &Args) -> Result<()> {
    let (handle, _) = spawn_coordinator(args)?;
    let scfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:8090").to_string(),
        ..ServeConfig::default()
    };
    let server = serve::Server::start(handle, &scfg)?;
    let addr = server.local_addr();
    println!("serving on http://{addr}");
    println!("  curl http://{addr}/healthz");
    println!("  curl http://{addr}/metrics");
    println!(
        "  curl -N -X POST http://{addr}/v1/generate \\\n       \
         -d '{{\"prompt\":[61,32,115,101,97,32,61],\"max_new_tokens\":24}}'"
    );
    println!(
        "stdin EOF (Ctrl-D), SIGTERM/SIGINT, or POST /admin/shutdown (loopback) \
         shuts down gracefully"
    );
    // SIGTERM (systemd stop, container runtimes, kill) and Ctrl-C land in
    // the same graceful drain as stdin EOF instead of killing the process
    signal::hook_termination();
    // stdin is watched from a side thread so the main loop can also poll
    // the /admin/shutdown flag — EOF alone used to be the only way out,
    // which headless callers (no tty, piped stdin held open) cannot send
    let eof = Arc::new(AtomicBool::new(false));
    {
        let eof = eof.clone();
        std::thread::spawn(move || {
            let mut line = String::new();
            loop {
                match std::io::stdin().read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => line.clear(),
                }
            }
            eof.store(true, Ordering::SeqCst);
        });
    }
    while !eof.load(Ordering::SeqCst)
        && !server.shutdown_requested()
        && !signal::termination_requested()
    {
        std::thread::sleep(Duration::from_millis(50));
    }
    let metrics = server.shutdown()?;
    println!("{}", metrics.report());
    Ok(())
}

/// One trace block for `BENCH_serve.json` (records what was replayed).
fn trace_json(cfg: &WorkloadConfig, wl: &Workload) -> Json {
    let arrival = match cfg.arrival {
        Arrival::Closed => Json::from("closed"),
        Arrival::Poisson { rate } => {
            Json::obj(vec![("kind", "poisson".into()), ("rate", rate.into())])
        }
        Arrival::Bursty { rate_on, rate_off, mean_on_s, mean_off_s } => Json::obj(vec![
            ("kind", "bursty".into()),
            ("rate_on", rate_on.into()),
            ("rate_off", rate_off.into()),
            ("mean_on_s", mean_on_s.into()),
            ("mean_off_s", mean_off_s.into()),
        ]),
    };
    Json::obj(vec![
        ("requests", wl.requests.len().into()),
        ("arrival", arrival),
        ("seed", (cfg.seed as f64).into()),
        ("templates", cfg.n_templates.into()),
        ("template_frac", cfg.template_frac.into()),
        ("sampled_frac", cfg.sampled_frac.into()),
        ("straggler_frac", cfg.straggler_frac.into()),
        ("class_mix", Json::Arr(cfg.class_mix.iter().map(|&w| Json::Num(w)).collect())),
        ("drop_frac", cfg.drop_frac.into()),
        ("total_output_budget", wl.total_output_budget().into()),
        ("max_seq_needed", wl.max_seq().into()),
    ])
}

/// Parse `--class-mix i,s,b` — the interactive/standard/batch arrival
/// weights for the workload generator.
fn parse_class_mix(args: &Args) -> Result<[f64; N_CLASSES]> {
    let Some(s) = args.get("class-mix") else {
        return Ok(WorkloadConfig::default().class_mix);
    };
    let parts: Vec<f64> = s
        .split(',')
        .map(|p| p.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("--class-mix expects comma-separated weights, got '{s}'"))?;
    ensure!(
        parts.len() == N_CLASSES,
        "--class-mix expects {N_CLASSES} weights (interactive,standard,batch), got {}",
        parts.len()
    );
    Ok([parts[0], parts[1], parts[2]])
}

/// Trace-driven open-loop load harness: replay one seeded workload trace
/// twice — straight into the coordinator, then over HTTP loopback — and
/// write both latency rows (TTFT/ITL/e2e percentiles, goodput, shed
/// rate) to `BENCH_serve.json`. The difference between the rows is the
/// measured server tax.
pub fn cmd_loadgen(args: &Args) -> Result<()> {
    let rate = args.get_f64("rate", 16.0)?;
    let arrival = if args.flag("bursty") {
        Arrival::Bursty {
            rate_on: 2.0 * rate,
            rate_off: 0.1 * rate,
            mean_on_s: 0.2,
            mean_off_s: 0.2,
        }
    } else if rate > 0.0 {
        Arrival::Poisson { rate }
    } else {
        Arrival::Closed
    };
    let wl_cfg = WorkloadConfig {
        n_requests: args.get_usize("requests", 32)?,
        arrival,
        seed: args.get_u64("seed", 7)?,
        class_mix: parse_class_mix(args)?,
        drop_frac: args.get_f64("drop-frac", 0.0)?,
        ..WorkloadConfig::default()
    };
    let corpus = TokenStream::load(&artifacts().join("data/corpus_val.fbqw")).ok();
    let trace = workload::generate(&wl_cfg, corpus.as_ref());

    // mode 1: in-process (scheduler + engine, no HTTP)
    let (handle, max_seq) = spawn_coordinator(args)?;
    let mut wl = trace.clone();
    wl.clamp_to(max_seq);
    crate::log_info!("replaying {} requests in-process (max_seq {max_seq})", wl.requests.len());
    let res_in = harness::run_in_process(&handle.client(), &wl);
    let metrics_in = handle.shutdown()?;

    // mode 2: the same trace over HTTP loopback (server tax on top)
    let (handle, _) = spawn_coordinator(args)?;
    let server = serve::Server::start(handle, &ServeConfig::default())?;
    crate::log_info!("replaying the same trace over http://{}", server.local_addr());
    let res_http = harness::run_http(server.local_addr(), &wl);
    // Optional observability dumps, scraped from the live server before
    // shutdown so they exercise the real endpoints (the CI serve-smoke
    // job validates both artifacts).
    if let Some(path) = args.get("prom-out") {
        let (code, body) =
            serve::client::get(server.local_addr(), "/metrics?format=prometheus")?;
        ensure!(code == 200, "prometheus scrape answered {code}");
        std::fs::write(path, &body)?;
        println!("wrote {path} (prometheus text exposition)");
    }
    if let Some(path) = args.get("trace-out") {
        let (code, body) = serve::client::get(server.local_addr(), "/debug/trace")?;
        ensure!(code == 200, "/debug/trace answered {code}");
        std::fs::write(path, &body)?;
        println!("wrote {path} (chrome trace-event json)");
    }
    let metrics_http = server.shutdown()?;

    for res in [&res_in, &res_http] {
        println!(
            "{:<11} {} done / {} shed / {} dropped of {} in {:.2}s | goodput {:.0} tok/s",
            res.mode,
            res.completed(),
            res.shed(),
            res.dropped(),
            res.records.len(),
            res.wall_s,
            res.goodput_tps(),
        );
        ensure!(res.shed_rate() <= 1.0, "{} shed rate out of range", res.mode);
    }
    let doc = Json::obj(vec![
        ("bench", "serve_loadgen".into()),
        ("trace", trace_json(&wl_cfg, &wl)),
        ("modes", Json::Arr(vec![res_in.to_json(), res_http.to_json()])),
        (
            "coordinator",
            Json::obj(vec![
                ("in_process", metrics_in.to_json()),
                ("http", metrics_http.to_json()),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string_pretty())?;
    println!("wrote BENCH_serve.json (in_process vs http on the same seeded trace)");
    Ok(())
}

pub fn cmd_inspect_weights(args: &Args) -> Result<()> {
    let store = load_store(args)?;
    println!(
        "model={} family={} scheme={} method={} bits={} group={} rank={}",
        store.cfg.name,
        store.cfg.family.name(),
        store.scheme,
        store.method,
        store.bits,
        store.group,
        store.rank
    );
    println!("resident bytes: {}", crate::util::human_bytes(store.resident_bytes()));
    for l in 0..store.cfg.n_layers {
        for lname in store.cfg.linear_names() {
            let prefix = format!("l{l}.{lname}");
            let lw = store.linear(&prefix)?;
            let w = lw.effective_dense();
            let (out, cin) = store.cfg.linear_shape(lname);
            let norm: f64 = w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            println!(
                "  {prefix:12} [{out:4}x{cin:4}] quant={} |W|_F={norm:.3} bytes={}",
                lw.is_quant(),
                crate::util::human_bytes(lw.resident_bytes())
            );
        }
    }
    Ok(())
}
