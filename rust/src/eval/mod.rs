//! Evaluation harness: the measurement side of every paper table/figure.
//!
//! * [`data`] — loaders for the corpus/task/judge archives,
//! * [`ppl`] — perplexity (Table 1),
//! * [`zeroshot`] — multiple-choice accuracy, lm-eval style (Tables 2–8),
//! * [`judge`] — pairwise win/tie/loss protocol (Fig 6),
//! * [`commands`] — the `fbquant` CLI entry points.

pub mod commands;
pub mod data;
pub mod judge;
pub mod ppl;
pub mod scorer;
pub mod zeroshot;

pub use data::{JudgeSet, McTask, TokenStream};
pub use scorer::{NativeScorer, PjrtScorer, Scorer};
