//! Perplexity evaluation (Table 1's metric).
//!
//! Byte-level perplexity over non-overlapping windows of the validation
//! stream. Window length defaults to 128 — the training context length
//! (the gptoid family's learned positions are untrained beyond it).

use super::data::TokenStream;
use super::scorer::Scorer;
use anyhow::Result;

#[derive(Debug, Clone, Copy)]
pub struct PplConfig {
    pub seq: usize,
    pub max_tokens: usize,
}

impl Default for PplConfig {
    fn default() -> Self {
        PplConfig { seq: 128, max_tokens: 16_384 }
    }
}

#[derive(Debug, Clone)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
}

pub fn perplexity(
    scorer: &mut dyn Scorer,
    stream: &TokenStream,
    cfg: PplConfig,
) -> Result<PplResult> {
    let max_windows = cfg.max_tokens / cfg.seq;
    let windows = stream.windows(cfg.seq, max_windows);
    let mut total_ll = 0f64;
    let mut total_n = 0usize;
    for w in &windows {
        total_ll += scorer.sum_ll(w, 0)?;
        total_n += w.len() - 1;
    }
    let nll = -total_ll / total_n.max(1) as f64;
    Ok(PplResult { ppl: nll.exp(), nll_per_token: nll, tokens: total_n })
}

/// Batched variant for the PJRT score artifact (reduces dispatch count).
pub fn perplexity_batched(
    scorer: &mut super::scorer::PjrtScorer,
    stream: &TokenStream,
    cfg: PplConfig,
) -> Result<PplResult> {
    use crate::tensor::ops;

    let max_windows = cfg.max_tokens / cfg.seq;
    let windows = stream.windows(cfg.seq, max_windows);
    let v = scorer.cfg().vocab;
    let mut total_ll = 0f64;
    let mut total_n = 0usize;
    for chunk in windows.chunks(4) {
        let inputs: Vec<&[u32]> = chunk.iter().map(|w| &w[..w.len() - 1]).collect();
        let batch_logits = scorer.logits_batch(&inputs)?;
        for (w, logits) in chunk.iter().zip(batch_logits) {
            for t in 0..w.len() - 1 {
                let row = &logits[t * v..(t + 1) * v];
                total_ll += ops::log_softmax_at(row, w[t + 1] as usize) as f64;
            }
            total_n += w.len() - 1;
        }
    }
    let nll = -total_ll / total_n.max(1) as f64;
    Ok(PplResult { ppl: nll.exp(), nll_per_token: nll, tokens: total_n })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Config;

    struct UniformScorer {
        cfg: Config,
    }

    impl Scorer for UniformScorer {
        fn cfg(&self) -> &Config {
            &self.cfg
        }

        fn logits(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
            Ok(vec![0f32; tokens.len() * self.cfg.vocab])
        }
    }

    #[test]
    fn uniform_model_ppl_is_vocab_size() {
        let j = crate::util::json::Json::parse(
            r#"{"name":"u","family":"llamoid","d_model":8,"n_layers":1,
                "n_heads":2,"d_ff":8,"vocab":32,"max_seq":512}"#,
        )
        .unwrap();
        let mut s = UniformScorer { cfg: Config::from_json(&j).unwrap() };
        let stream = TokenStream::from_vec((0..2000u32).map(|i| (i % 31) as u8).collect());
        let r = perplexity(&mut s, &stream, PplConfig { seq: 64, max_tokens: 1024 }).unwrap();
        assert!((r.ppl - 32.0).abs() < 1e-3, "ppl={}", r.ppl);
        assert_eq!(r.tokens, 16 * 64);
    }
}
