fn main() {
    if let Err(e) = fbquant::util::cli::run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
