//! Model-level plumbing: configurations, weight stores and the byte
//! tokenizer. The actual compute lives in [`crate::engine`] (native) and
//! [`crate::runtime`] (PJRT).

pub mod config;
pub mod tokenizer;
pub mod weights;

pub use config::{Config, Family};
pub use tokenizer::ByteTokenizer;
pub use weights::{LinearWeights, WeightStore};
