//! Byte-level tokenizer (spec shared with `python/compile/tokenizer.py`,
//! asserted against `artifacts/data/vocab.json` at load time).

use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    pub vocab_size: usize,
    pub bos_id: u32,
    pub pad_id: u32,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        ByteTokenizer { vocab_size: 256, bos_id: 0, pad_id: 0 }
    }
}

impl ByteTokenizer {
    /// Load + validate the vocabulary spec written by the python side.
    pub fn from_spec(path: &Path) -> Result<ByteTokenizer> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
        if j.get("kind").and_then(|k| k.as_str()) != Some("byte") {
            bail!("unsupported tokenizer kind in {}", path.display());
        }
        Ok(ByteTokenizer {
            vocab_size: j.get("vocab_size").and_then(|v| v.as_usize()).unwrap_or(256),
            bos_id: j.get("bos_id").and_then(|v| v.as_usize()).unwrap_or(0) as u32,
            pad_id: j.get("pad_id").and_then(|v| v.as_usize()).unwrap_or(0) as u32,
        })
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::default();
        let ids = t.encode("the crab drifts.");
        assert_eq!(ids.len(), 16);
        assert_eq!(t.decode(&ids), "the crab drifts.");
    }

    #[test]
    fn utf8_multibyte_survives() {
        let t = ByteTokenizer::default();
        let ids = t.encode("café");
        assert_eq!(ids.len(), 5); // é is two bytes
        assert_eq!(t.decode(&ids), "café");
    }
}
