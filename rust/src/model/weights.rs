//! Weight store: a `.fbqw` checkpoint materialized for the engines.
//!
//! Supports both checkpoint kinds produced by the python build:
//! * `scheme: "fp"`   — float weights per linear (`<prefix>.w`),
//! * `scheme: "quant"` — per linear `<prefix>/codes_packed`, `scales`,
//!   `zeros` and optionally `a`, `b`, `col_scale`.
//!
//! For the PJRT runtime the store can also synthesize the *uniform*
//! quantized feed (zero-filled sub-branch / unit col_scale for methods
//! that lack them), since the AOT graphs take every tensor.

use super::config::Config;
use crate::quant::formats::Archive;
use crate::quant::pack::unpack_codes;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One linear layer's weights in whichever form the checkpoint provides.
#[derive(Debug, Clone)]
pub enum LinearWeights {
    Dense {
        /// `[out, in]`
        w: Vec<f32>,
        bias: Option<Vec<f32>>,
    },
    Quant {
        out: usize,
        cin: usize,
        bits: u8,
        group: usize,
        /// `[out, in/8]` nibble-packed codes
        packed: Vec<u32>,
        /// `[out, in/group]`
        scales: Vec<f32>,
        zeros: Vec<f32>,
        /// optional sub-branch A `[r, in]`, B `[out, r]`
        a: Option<Vec<f32>>,
        b: Option<Vec<f32>>,
        rank: usize,
        /// optional per-input-channel activation multiplier
        col_scale: Option<Vec<f32>>,
        bias: Option<Vec<f32>>,
    },
}

impl LinearWeights {
    pub fn is_quant(&self) -> bool {
        matches!(self, LinearWeights::Quant { .. })
    }

    /// Weight bytes resident at serving time (Fig. 1's memory axis).
    /// Quantized layers count the *logical* bit-width for codes.
    pub fn resident_bytes(&self) -> usize {
        match self {
            LinearWeights::Dense { w, bias } => {
                4 * w.len() + bias.as_ref().map_or(0, |b| 4 * b.len())
            }
            LinearWeights::Quant { out, cin, bits, scales, zeros, a, b, col_scale, bias, .. } => {
                let codes = out * cin * (*bits as usize) / 8;
                codes
                    + 4 * (scales.len() + zeros.len())
                    + a.as_ref().map_or(0, |v| 4 * v.len())
                    + b.as_ref().map_or(0, |v| 4 * v.len())
                    + col_scale.as_ref().map_or(0, |v| 4 * v.len())
                    + bias.as_ref().map_or(0, |v| 4 * v.len())
            }
        }
    }

    /// Unpacked int8 codes (PJRT feed path).
    pub fn unpacked_codes(&self) -> Result<Vec<i8>> {
        match self {
            LinearWeights::Quant { packed, out, cin, .. } => Ok(unpack_codes(packed, *out, *cin)),
            _ => bail!("dense layer has no codes"),
        }
    }

    /// The effective dense weight the layer applies (analysis/tests).
    pub fn effective_dense(&self) -> Vec<f32> {
        match self {
            LinearWeights::Dense { w, .. } => w.clone(),
            LinearWeights::Quant {
                out, cin, bits, group, packed, scales, zeros, a, b, col_scale, rank, ..
            } => {
                let codes = unpack_codes(packed, *out, *cin);
                let p = crate::quant::groupwise::QuantParams {
                    bits: *bits,
                    group: *group,
                    scales: scales.clone(),
                    zeros: zeros.clone(),
                };
                let mut w = crate::quant::groupwise::dequantize(&codes, *out, *cin, &p);
                if let (Some(a), Some(b)) = (a, b) {
                    let sb = crate::quant::subbranch::SubBranch::new(
                        a.clone(), b.clone(), *rank, *cin, *out,
                    );
                    let sigma = sb.dense_sigma();
                    for (wi, si) in w.iter_mut().zip(&sigma) {
                        *wi += si;
                    }
                }
                if let Some(cs) = col_scale {
                    for r in 0..*out {
                        for c in 0..*cin {
                            w[r * cin + c] *= cs[c];
                        }
                    }
                }
                w
            }
        }
    }
}

/// A loaded checkpoint: config + named float tensors + per-linear weights.
#[derive(Debug)]
pub struct WeightStore {
    pub cfg: Config,
    pub scheme: String,
    pub method: String,
    pub bits: u8,
    pub group: usize,
    pub rank: usize,
    floats: HashMap<String, Vec<f32>>,
    linears: HashMap<String, LinearWeights>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let arc = Archive::load(path)?;
        let cfg = Config::from_json(
            arc.meta.get("config").context("checkpoint meta missing 'config'")?,
        )?;
        let scheme = arc.meta_str("scheme").unwrap_or("fp").to_string();
        let method = arc.meta_str("method").unwrap_or("fp").to_string();
        let bits = arc.meta_usize("bits").unwrap_or(16) as u8;
        let group = arc.meta_usize("group").unwrap_or(128);
        let rank = arc.meta_usize("rank").unwrap_or(0);

        let mut floats = HashMap::new();
        for name in arc.names() {
            if !name.contains('/') {
                floats.insert(name.clone(), arc.get(name)?.as_f32()?);
            }
        }

        let mut linears = HashMap::new();
        for l in 0..cfg.n_layers {
            for lname in cfg.linear_names() {
                let prefix = format!("l{l}.{lname}");
                let (out, cin) = cfg.linear_shape(lname);
                let bias = floats.get(&format!("{prefix}.b")).cloned();
                let lw = if arc.contains(&format!("{prefix}/codes_packed")) {
                    let packed_t = arc.get(&format!("{prefix}/codes_packed"))?;
                    if packed_t.shape != vec![out, cin / 8] {
                        let ps = &packed_t.shape;
                        bail!("{prefix}: packed shape {ps:?} != [{out}, {}]", cin / 8);
                    }
                    let get_opt = |suffix: &str| -> Result<Option<Vec<f32>>> {
                        let n = format!("{prefix}/{suffix}");
                        if arc.contains(&n) {
                            Ok(Some(arc.get(&n)?.as_f32()?))
                        } else {
                            Ok(None)
                        }
                    };
                    let a = get_opt("a")?;
                    let b = get_opt("b")?;
                    let this_rank = a.as_ref().map_or(0, |av| av.len() / cin);
                    LinearWeights::Quant {
                        out,
                        cin,
                        bits,
                        group,
                        packed: packed_t.as_u32()?,
                        scales: arc.get(&format!("{prefix}/scales"))?.as_f32()?,
                        zeros: arc.get(&format!("{prefix}/zeros"))?.as_f32()?,
                        a,
                        b,
                        rank: this_rank,
                        col_scale: get_opt("col_scale")?,
                        bias,
                    }
                } else {
                    let w = floats
                        .get(&format!("{prefix}.w"))
                        .with_context(|| format!("missing weights for {prefix}"))?
                        .clone();
                    if w.len() != out * cin {
                        bail!("{prefix}: weight len {} != {}", w.len(), out * cin);
                    }
                    LinearWeights::Dense { w, bias }
                };
                linears.insert(prefix, lw);
            }
        }

        Ok(WeightStore { cfg, scheme, method, bits, group, rank, floats, linears })
    }

    pub fn float(&self, name: &str) -> Result<&[f32]> {
        self.floats
            .get(name)
            .map(|v| v.as_slice())
            .with_context(|| format!("checkpoint has no float tensor '{name}'"))
    }

    pub fn linear(&self, prefix: &str) -> Result<&LinearWeights> {
        self.linears
            .get(prefix)
            .with_context(|| format!("checkpoint has no linear '{prefix}'"))
    }

    pub fn is_quantized(&self) -> bool {
        self.scheme == "quant"
    }

    /// Total resident weight bytes (Fig. 1 memory axis).
    pub fn resident_bytes(&self) -> usize {
        let lin: usize = self.linears.values().map(|l| l.resident_bytes()).sum();
        let fl: usize = self
            .floats
            .iter()
            .filter(|(k, _)| !k.contains(".w") || !self.is_quantized_prefix(k))
            .map(|(_, v)| 4 * v.len())
            .sum();
        lin + fl
    }

    fn is_quantized_prefix(&self, key: &str) -> bool {
        key.strip_suffix(".w")
            .map(|p| self.linears.get(p).is_some_and(|l| l.is_quant()))
            .unwrap_or(false)
    }

    /// Checkpoint path convention: `<model>_<method>_w<bits>.fbqw` or
    /// `<model>_fp.fbqw` under `artifacts/models/`.
    pub fn path_for(artifacts: &Path, model: &str, method: &str, bits: u8) -> std::path::PathBuf {
        let file = if method == "fp" {
            format!("{model}_fp.fbqw")
        } else {
            format!("{model}_{method}_w{bits}.fbqw")
        };
        artifacts.join("models").join(file)
    }
}
