//! Model configuration, mirroring `python/compile/model.py::Config`.
//!
//! Configs are not hard-coded on the rust side: they are parsed from the
//! `config` object embedded in every `.fbqw` checkpoint's metadata, so the
//! rust binary follows whatever grid the python build produced.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Llamoid,
    Gptoid,
    Qwenoid,
}

impl Family {
    pub fn parse(s: &str) -> Result<Family> {
        Ok(match s {
            "llamoid" => Family::Llamoid,
            "gptoid" => Family::Gptoid,
            "qwenoid" => Family::Qwenoid,
            other => bail!("unknown model family '{other}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Family::Llamoid => "llamoid",
            Family::Gptoid => "gptoid",
            Family::Qwenoid => "qwenoid",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    pub name: String,
    pub family: Family,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
}

impl Config {
    pub fn from_json(j: &Json) -> Result<Config> {
        let get = |k: &str| -> Result<usize> {
            j.get(k).and_then(|v| v.as_usize()).with_context(|| format!("config missing '{k}'"))
        };
        Ok(Config {
            name: j.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            family: Family::parse(
                j.get("family").and_then(|v| v.as_str()).context("config missing 'family'")?,
            )?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            vocab: get("vocab")?,
            max_seq: get("max_seq")?,
            rope_theta: j.get("rope_theta").and_then(|v| v.as_f64()).unwrap_or(10_000.0) as f32,
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn gated(&self) -> bool {
        matches!(self.family, Family::Llamoid | Family::Qwenoid)
    }

    pub fn rms(&self) -> bool {
        matches!(self.family, Family::Llamoid | Family::Qwenoid)
    }

    pub fn rope(&self) -> bool {
        matches!(self.family, Family::Llamoid | Family::Qwenoid)
    }

    pub fn qkv_bias(&self) -> bool {
        self.family == Family::Qwenoid
    }

    pub fn mlp_bias(&self) -> bool {
        self.family == Family::Gptoid
    }

    /// The quantizable projections of one block, in canonical order.
    pub fn linear_names(&self) -> &'static [&'static str] {
        if self.gated() {
            &["q", "k", "v", "o", "gate", "up", "down"]
        } else {
            &["q", "k", "v", "o", "fc", "proj"]
        }
    }

    /// `(out, in)` of a named projection.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let (d, ff) = (self.d_model, self.d_ff);
        match name {
            "q" | "k" | "v" | "o" => (d, d),
            "gate" | "up" | "fc" => (ff, d),
            "down" | "proj" => (d, ff),
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub fn n_params(&self) -> usize {
        let mut n = 2 * self.vocab * self.d_model;
        if !self.rope() {
            n += self.max_seq * self.d_model;
        }
        let per: usize = self
            .linear_names()
            .iter()
            .map(|l| {
                let (o, i) = self.linear_shape(l);
                o * i
            })
            .sum();
        n + self.n_layers * per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_json() -> Json {
        Json::parse(
            r#"{"name":"llamoid-tiny","family":"llamoid","d_model":128,
                "n_layers":2,"n_heads":4,"d_ff":384,"vocab":256,
                "max_seq":256,"rope_theta":10000.0}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_derives() {
        let cfg = Config::from_json(&demo_json()).unwrap();
        assert_eq!(cfg.family, Family::Llamoid);
        assert_eq!(cfg.head_dim(), 32);
        assert!(cfg.gated() && cfg.rms() && cfg.rope());
        assert!(!cfg.qkv_bias() && !cfg.mlp_bias());
        assert_eq!(cfg.linear_names().len(), 7);
        assert_eq!(cfg.linear_shape("down"), (128, 384));
        // matches python Config.n_params for this shape
        assert_eq!(cfg.n_params(), 2 * 256 * 128 + 2 * (4 * 128 * 128 + 3 * 128 * 384));
    }

    #[test]
    fn rejects_unknown_family() {
        let j = Json::parse(r#"{"family":"mamba","d_model":8,"n_layers":1,"n_heads":1,"d_ff":8,"vocab":256,"max_seq":8}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }
}
