//! Fig. 6: pairwise quantized-model comparison, 3-bit, deterministic
//! judge (per-question held-out loss, both orders = 160 trials/pair).
//!
//! Paper shape (Llama3-8B-chat): FBQuant achieves the highest win+tie
//! rates against AWQ, OmniQuant, CALDERA and SVDQuant.

mod common;

use common::*;
use fbquant::eval::data::JudgeSet;
use fbquant::eval::judge::{compare, question_nlls};

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("fig6: run `make artifacts` first");
        return Ok(());
    }
    let set = JudgeSet::load(&artifacts().join("data/judge.fbqw"))?;
    let model = "llamoid-tiny";
    let bits = 3u8;
    let margin = 0.02;
    let opponents = if fast() {
        vec!["awq"]
    } else {
        vec!["awq", "omniquant", "caldera", "svdquant"]
    };

    println!(
        "\n=== Fig 6: pairwise comparison, {model} w{bits} ({} questions x 2 orders) ===",
        set.len()
    );
    let mut fbq = native_scorer(model, "fbquant", bits)?;
    let nll_fbq = question_nlls(&mut fbq, &set)?;

    println!("{:<24} {:>8} {:>8} {:>8} {:>10}", "pair", "win%", "tie%", "loss%", "win+tie%");
    println!("{}", "-".repeat(64));
    for opp in opponents {
        let mut sc = native_scorer(model, opp, bits)?;
        let nll_opp = question_nlls(&mut sc, &set)?;
        let r = compare(&nll_fbq, &nll_opp, margin);
        println!(
            "{:<24} {:>8.1} {:>8.1} {:>8.1} {:>10.1}",
            format!("fbquant vs {opp}"),
            r.win_pct(),
            r.tie_pct(),
            r.loss_pct(),
            r.win_tie_pct()
        );
    }
    println!("\npaper: FBQuant 79.3% win+tie vs AWQ, 90.0% vs SVDQuant (GPT-4 judge).");
    Ok(())
}
