//! Table 1: perplexity on the held-out validation set across the method
//! zoo × {W4, W3} × the model grid.
//!
//! Paper shape to reproduce: FP < FBQuant < {GPTQ, AWQ, OmniQuant,
//! CALDERA, SVDQuant} < RTN, with the gap widening at 3 bits.

mod common;

use common::*;
use fbquant::eval::data::TokenStream;
use fbquant::eval::ppl::{perplexity, PplConfig};

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("table1_perplexity: run `make artifacts` first");
        return Ok(());
    }
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let cfg = PplConfig { seq: 128, max_tokens: if fast() { 2048 } else { 4096 } };
    let models = bench_models();

    println!("\n=== Table 1: WikiText2-analog validation perplexity (lower is better) ===");
    println!(
        "(seq={} tokens={}; group=128; rank=d/8; see EXPERIMENTS.md)",
        cfg.seq, cfg.max_tokens
    );
    let mut header = format!("{:<10} {:>5}", "Method", "WBit");
    for m in &models {
        header.push_str(&format!(" {:>14}", m));
    }
    println!("{header}");
    println!("{}", "-".repeat(header.len()));

    let mut rows: Vec<(String, u8)> = vec![("fp".into(), 16)];
    for &bits in &[4u8, 3] {
        for &m in METHODS {
            rows.push((m.into(), bits));
        }
    }

    for (method, bits) in rows {
        let mut line = format!("{:<10} {:>5}", method, bits);
        for model in models.iter() {
            match native_scorer(model, &method, bits) {
                Ok(mut scorer) => {
                    let r = perplexity(&mut scorer, &stream, cfg)?;
                    line.push_str(&format!(" {:>14.4}", r.ppl));
                }
                Err(_) => line.push_str(&format!(" {:>14}", "-")),
            }
        }
        println!("{line}");
    }

    println!("\nExtra baselines (LoftQ, EoRA — built beyond the paper's table):");
    for &bits in &[4u8, 3] {
        for &m in EXTRA_METHODS {
            let mut line = format!("{:<10} {:>5}", m, bits);
            for model in &models {
                match native_scorer(model, m, bits) {
                    Ok(mut scorer) => {
                        let r = perplexity(&mut scorer, &stream, cfg)?;
                        line.push_str(&format!(" {:>14.4}", r.ppl));
                    }
                    Err(_) => line.push_str(&format!(" {:>14}", "-")),
                }
            }
            println!("{line}");
        }
    }
    Ok(())
}
