//! Shared plumbing for the paper-table benches.

#![allow(dead_code)]

use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::scorer::NativeScorer;
use fbquant::model::WeightStore;
use std::path::PathBuf;

pub const MODELS: &[&str] = &[
    "llamoid-tiny",
    "llamoid-small",
    "llamoid-base",
    "gptoid-tiny",
    "gptoid-small",
    "qwenoid-tiny",
];

/// Paper method order (Tables 1–8) + the two extra baselines we also built.
pub const METHODS: &[&str] =
    &["rtn", "gptq", "awq", "omniquant", "caldera", "svdquant", "fbquant"];
pub const EXTRA_METHODS: &[&str] = &["loftq", "eora"];

pub fn artifacts() -> PathBuf {
    fbquant::artifacts_dir()
}

pub fn have_artifacts() -> bool {
    artifacts().join("data/vocab.json").exists()
}

pub fn ckpt(model: &str, method: &str, bits: u8) -> PathBuf {
    WeightStore::path_for(&artifacts(), model, method, bits)
}

pub fn native_scorer(model: &str, method: &str, bits: u8) -> anyhow::Result<NativeScorer> {
    let store = WeightStore::load(&ckpt(model, method, bits))?;
    Ok(NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused)?))
}

/// `FBQ_BENCH_FAST=1` shrinks grids for smoke runs.
pub fn fast() -> bool {
    fbquant::bench::fast_mode()
}

pub fn bench_models() -> Vec<&'static str> {
    if fast() {
        vec!["llamoid-tiny"]
    } else {
        MODELS.to_vec()
    }
}
