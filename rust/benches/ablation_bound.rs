//! §4.1 ablation: the reconstruction bound (Eq. 13) measured on real
//! checkpoints.
//!
//! For every quantized linear layer, compute max|W − W_eff| and compare to
//! the bound max(s/2) of its quantizer grid. FBQuant must satisfy the
//! bound layer-by-layer; conventional sub-branch methods (LoftQ, CALDERA,
//! SVDQuant, EoRA) have no such guarantee — their excess is reported.

mod common;

use common::*;
use fbquant::model::WeightStore;
use fbquant::quant::subbranch;

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("ablation_bound: run `make artifacts` first");
        return Ok(());
    }
    let fp = WeightStore::load(&ckpt("llamoid-tiny", "fp", 4))?;
    let methods = ["rtn", "fbquant", "loftq", "caldera", "svdquant", "eora"];
    let bits = 3u8;

    println!("\n=== Ablation (§4.1): max reconstruction deviation vs the s/2 bound (w{bits}) ===");
    println!(
        "{:<10} {:>14} {:>14} {:>10} {:>8}",
        "method", "max|W-W'|", "max bound", "ratio", "bounded"
    );
    println!("{}", "-".repeat(62));

    for method in methods {
        let store = WeightStore::load(&ckpt("llamoid-tiny", method, bits))?;
        let mut worst_dev = 0f32;
        let mut worst_bound = 0f32;
        let mut all_bounded = true;
        for l in 0..store.cfg.n_layers {
            for lname in store.cfg.linear_names() {
                let prefix = format!("l{l}.{lname}");
                let (out, cin) = store.cfg.linear_shape(lname);
                let w = match fp.linear(&prefix)? {
                    fbquant::model::LinearWeights::Dense { w, .. } => w.clone(),
                    _ => unreachable!(),
                };
                let lw = store.linear(&prefix)?;
                let w_eff_nocs = {
                    // exclude col_scale: the bound is about the weight grid
                    let mut q = lw.clone();
                    if let fbquant::model::LinearWeights::Quant { col_scale, .. } = &mut q {
                        *col_scale = None;
                    }
                    q.effective_dense()
                };
                // Σ for the bound: the stored sub-branch (zero if absent)
                let sigma = match lw {
                    fbquant::model::LinearWeights::Quant { a: Some(a), b: Some(b), rank, .. } => {
                        subbranch::SubBranch::new(a.clone(), b.clone(), *rank, cin, out)
                            .dense_sigma()
                    }
                    _ => vec![0f32; out * cin],
                };
                let bound =
                    subbranch::fbq_bound(&w, &sigma, out, cin, bits, store.group);
                for i in 0..w.len() {
                    let dev = (w[i] - w_eff_nocs[i]).abs();
                    if dev > worst_dev {
                        worst_dev = dev;
                    }
                    if bound[i] > worst_bound {
                        worst_bound = bound[i];
                    }
                    if dev > bound[i] + 1e-4 {
                        all_bounded = false;
                    }
                }
            }
        }
        println!(
            "{:<10} {:>14.4} {:>14.4} {:>10.2} {:>8}",
            method,
            worst_dev,
            worst_bound,
            worst_dev / worst_bound.max(1e-9),
            if all_bounded { "yes" } else { "NO" }
        );
    }
    println!(
        "\nexpected: rtn + fbquant bounded; conventional sub-branch methods \
         exceed the grid bound."
    );
    Ok(())
}
