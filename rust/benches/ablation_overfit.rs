//! §3.1 ablation: calibration-set size vs validation perplexity.
//!
//! The paper's central theoretical claim: the conventional sub-branch
//! objective is ill-posed — with limited calibration data, components in
//! the near-null space of XᵀX are unconstrained, so CALDERA-style
//! optimization overfits as the calibration set shrinks. FBQuant's
//! feedback bound makes it insensitive.
//!
//! Requires the calibration-sweep checkpoints:
//!   cd python && python -m compile.quantize_all --model llamoid-tiny \
//!       --method caldera,fbquant --bits 3 --calib-seqs N --tag calN
//! (produced by `make artifacts`' sweep stage).

mod common;

use common::*;
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::eval::ppl::{perplexity, PplConfig};
use fbquant::eval::scorer::NativeScorer;
use fbquant::model::WeightStore;

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("ablation_overfit: run `make artifacts` first");
        return Ok(());
    }
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let cfg = PplConfig { seq: 128, max_tokens: if fast() { 2048 } else { 8192 } };
    // total calibration tokens: 64 < d_in=128 puts XᵀX rank-deficient —
    // the §3.1 ill-posed regime. 32768 = the full paper-protocol set.
    let sweeps: &[(usize, &str)] = &[
        (64, "_tok64"),
        (256, "_tok256"),
        (1024, "_tok1024"),
        (32768, ""),
    ];
    let methods = ["caldera", "fbquant"];

    println!("\n=== Ablation (§3.1): calibration tokens vs val perplexity (llamoid-tiny, w3) ===");
    println!("{:<10} {:>12} {:>12} {:>12}", "method", "calib toks", "val ppl", "recon loss");
    println!("{}", "-".repeat(50));
    for method in methods {
        for &(n, tag) in sweeps {
            let path = artifacts()
                .join("models")
                .join(format!("llamoid-tiny_{method}_w3{tag}.fbqw"));
            if !path.exists() {
                println!("{:<10} {:>10} {:>12}", method, n, "(missing)");
                continue;
            }
            let store = WeightStore::load(&path)?;
            let recon = store_recon_loss(&path)?;
            let mut scorer =
                NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused)?);
            let r = perplexity(&mut scorer, &stream, cfg)?;
            println!("{:<10} {:>10} {:>12.4} {:>12.3e}", method, n, r.ppl, recon);
        }
        println!();
    }
    println!("reading: as calibration shrinks below d_in tokens, caldera's CALIBRATION\n\
              loss improves (64-token recon ≈ 45% lower than full-set) while val ppl\n\
              does NOT — fitting calibration noise, the §3.1 decoupling signature.\n\
              fbquant's val ppl stays flat and its weights stay inside the Eq. 13\n\
              bound at every size (see `ablation_bound` for the bound check).");
    Ok(())
}

fn store_recon_loss(path: &std::path::Path) -> anyhow::Result<f64> {
    let arc = fbquant::quant::formats::Archive::load(path)?;
    Ok(arc
        .meta
        .get("mean_recon_loss")
        .and_then(|j| j.as_f64())
        .unwrap_or(f64::NAN))
}
