//! Design-choice sweeps: sub-branch rank, group size and bit-width vs
//! validation perplexity (llamoid-tiny, fbquant).
//!
//! Requires the sweep checkpoints produced by `make artifacts`
//! (quantize_all with --rank/--group/--bits and matching --tag).

mod common;

use common::*;
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::eval::ppl::{perplexity, PplConfig};
use fbquant::eval::scorer::NativeScorer;
use fbquant::model::WeightStore;

fn eval(path: &std::path::Path, stream: &TokenStream, cfg: PplConfig) -> Option<(f64, usize)> {
    let store = WeightStore::load(path).ok()?;
    let mut scorer = NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused).ok()?);
    let r = perplexity(&mut scorer, stream, cfg).ok()?;
    Some((r.ppl, store.resident_bytes()))
}

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("ablation_sweeps: run `make artifacts` first");
        return Ok(());
    }
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let cfg = PplConfig { seq: 128, max_tokens: if fast() { 2048 } else { 4096 } };
    let dir = artifacts().join("models");

    println!("\n=== Sweep: sub-branch rank (llamoid-tiny fbquant w3, group 128) ===");
    println!("{:<22} {:>10} {:>14}", "checkpoint", "val ppl", "bytes");
    for (rank, tag) in [(8, "_r8"), (16, ""), (32, "_r32"), (64, "_r64")] {
        let path = dir.join(format!("llamoid-tiny_fbquant_w3{tag}.fbqw"));
        match eval(&path, &stream, cfg) {
            Some((ppl, bytes)) => println!(
                "{:<22} {:>10.4} {:>14}",
                format!("rank={rank}"),
                ppl,
                fbquant::util::human_bytes(bytes)
            ),
            None => println!("{:<22} {:>10}", format!("rank={rank}"), "(missing)"),
        }
    }

    println!("\n=== Sweep: group size (llamoid-tiny fbquant w3, rank 16) ===");
    for (group, tag) in [(32usize, "_g32"), (64, "_g64"), (128, "")] {
        let path = dir.join(format!("llamoid-tiny_fbquant_w3{tag}.fbqw"));
        match eval(&path, &stream, cfg) {
            Some((ppl, bytes)) => println!(
                "{:<22} {:>10.4} {:>14}",
                format!("group={group}"),
                ppl,
                fbquant::util::human_bytes(bytes)
            ),
            None => println!("{:<22} {:>10}", format!("group={group}"), "(missing)"),
        }
    }

    println!("\n=== Sweep: bit-width (llamoid-tiny fbquant, group 128, rank 16) ===");
    for bits in [2u8, 3, 4] {
        let path = dir.join(format!("llamoid-tiny_fbquant_w{bits}.fbqw"));
        match eval(&path, &stream, cfg) {
            Some((ppl, bytes)) => println!(
                "{:<22} {:>10.4} {:>14}",
                format!("bits={bits}"),
                ppl,
                fbquant::util::human_bytes(bytes)
            ),
            None => println!("{:<22} {:>10}", format!("bits={bits}"), "(missing)"),
        }
    }
    Ok(())
}
