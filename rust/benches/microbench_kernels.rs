//! Kernel-level microbenchmarks across the stack:
//! * rust native quantized GEMV/GEMM (fused / unfused / no-sub) across
//!   sizes, with effective bandwidth,
//! * dense FP GEMV for the roofline reference,
//! * the batched-decode sweep (slots × bits × rank): weight-stationary
//!   `gemv_multi` vs the per-slot loop, emitted to `BENCH_decode.json`
//!   (tokens/s + weight bytes/token) as the perf trajectory file CI
//!   smokes on every push,
//! * the PJRT `kernel_fused`/`kernel_unfused` artifacts (the Pallas
//!   pair lowered by aot.py) — dispatch-count effect at the XLA level.

mod common;

use common::*;
use fbquant::bench::Bench;
use fbquant::engine::kernels::{QuantLinear, SubMode, Traffic, Workspace};
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::util::Pcg64;

fn layer(d: usize, r: usize, bits: u8) -> (QuantLinear, Vec<f32>) {
    let mut rng = Pcg64::seeded(6);
    let w: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
    let p = groupwise::quant_params(&w, d, d, bits, 128.min(d));
    let codes = groupwise::quantize(&w, d, d, &p);
    (
        QuantLinear {
            out: d,
            cin: d,
            bits,
            group: 128.min(d),
            packed: pack_codes(&codes, d, d),
            scales: p.scales,
            zeros: p.zeros,
            rank: r,
            a: Some((0..r * d).map(|_| rng.normal() as f32 * 0.02).collect()),
            b: Some((0..d * r).map(|_| rng.normal() as f32 * 0.02).collect()),
            col_scale: None,
            bias: None,
        },
        w,
    )
}

/// Batched-decode sweep: the weight-stationary `gemv_multi` against the
/// per-slot `gemv` loop over slots × bits × rank, on one square decode
/// layer as the per-layer proxy. Emits `BENCH_decode.json` so the perf
/// trajectory (tokens/s, weight bytes/token) is tracked from CI.
fn batched_decode_sweep(bench: &Bench) -> anyhow::Result<()> {
    use fbquant::util::json::Json;

    let d: usize = if fast() { 256 } else { 512 };
    let bits_list: &[u8] = if fast() { &[4] } else { &[3, 4] };
    let rank_list: &[usize] = &[0, 16];
    let slot_list: &[usize] = &[1, 2, 4, 8];

    println!("\n=== batched decode sweep: weight-stationary gemv_multi vs per-slot gemv (d={d}) ===");
    println!(
        "{:<5} {:<5} {:<5} {:<12} {:>11} {:>12} {:>13} {:>9}",
        "bits", "rank", "m", "impl", "latency(us)", "tokens/s", "W bytes/tok", "speedup"
    );
    println!("{}", "-".repeat(80));

    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Pcg64::seeded(9);
    for &bits in bits_list {
        for &rank in rank_list {
            let (mut ql, _) = layer(d, rank, bits);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            for &m in slot_list {
                let xs: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let mut ys = vec![0f32; m * d];
                let mut ws = Workspace::default();

                let mut results = Vec::new();
                for batched in [false, true] {
                    let mut t = Traffic::default();
                    if batched {
                        ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut t);
                    } else {
                        for i in 0..m {
                            ql.gemv(
                                &xs[i * d..(i + 1) * d],
                                &mut ys[i * d..(i + 1) * d],
                                SubMode::Fused,
                                &mut ws,
                                &mut t,
                            );
                        }
                    }
                    let wbpt = t.weight_bytes as f64 / m as f64;
                    let name = if batched { "batched" } else { "sequential" };
                    let r = bench.run(name, || {
                        let mut tt = Traffic::default();
                        if batched {
                            ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut tt);
                        } else {
                            for i in 0..m {
                                ql.gemv(
                                    &xs[i * d..(i + 1) * d],
                                    &mut ys[i * d..(i + 1) * d],
                                    SubMode::Fused,
                                    &mut ws,
                                    &mut tt,
                                );
                            }
                        }
                    });
                    let tps = m as f64 / r.min_s;
                    results.push((name, r.min_us(), tps, wbpt));
                }
                let speedup = results[1].2 / results[0].2;
                for (name, lat_us, tps, wbpt) in &results {
                    println!(
                        "{:<5} {:<5} {:<5} {:<12} {:>11.1} {:>12.0} {:>13.0} {:>9}",
                        bits,
                        rank,
                        m,
                        name,
                        lat_us,
                        tps,
                        wbpt,
                        if *name == "batched" { format!("{speedup:.2}x") } else { String::new() },
                    );
                    rows.push(Json::obj(vec![
                        ("d", Json::from(d)),
                        ("bits", Json::from(bits as usize)),
                        ("rank", Json::from(rank)),
                        ("slots", Json::from(m)),
                        ("impl", Json::from(*name)),
                        ("latency_us", Json::from(*lat_us)),
                        ("tokens_per_s", Json::from(*tps)),
                        ("weight_bytes_per_token", Json::from(*wbpt)),
                    ]));
                }
            }
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::from("batched_decode_sweep")),
        ("unit", Json::from("per-layer decode proxy (one square quantized linear)")),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_decode.json", doc.to_string_pretty())?;
    println!("\nwrote BENCH_decode.json ({} rows)", slot_list.len() * bits_list.len() * rank_list.len() * 2);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let sizes: &[usize] = if fast() { &[256, 512] } else { &[256, 512, 1024] };
    let iters = if fast() { 3 } else { 8 };
    let bench = Bench::new(2, iters);

    println!("\n=== native kernel microbench: quantized GEMV (decode shape, m=1) ===");
    println!(
        "{:<6} {:<14} {:>11} {:>12} {:>10}",
        "d", "impl", "latency(us)", "GB/s eff.", "launches"
    );
    println!("{}", "-".repeat(58));
    for &d in sizes {
        let (ql, w) = layer(d, d / 32, 4);
        let mut rng = Pcg64::seeded(7);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0f32; d];
        let mut ws = Workspace::default();

        // dense reference
        let rd = bench.run("dense", || {
            for o in 0..d {
                y[o] = fbquant::tensor::ops::dot(&x, &w[o * d..(o + 1) * d]);
            }
        });
        println!(
            "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
            d, "FP32-dense", rd.mean_us(),
            (4 * d * d) as f64 / rd.mean_s / 1e9, 1
        );

        for (name, mode) in [
            ("INT4", SubMode::None),
            ("INT4-Sub", SubMode::Unfused),
            ("INT4-FBQuant", SubMode::Fused),
        ] {
            let mut t = Traffic::default();
            ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
            let bytes = t.total_bytes();
            let launches = t.kernel_launches;
            let r = bench.run(name, || {
                let mut tt = Traffic::default();
                ql.gemv(&x, &mut y, mode, &mut ws, &mut tt);
            });
            println!(
                "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
                d, name, r.mean_us(),
                bytes as f64 / r.mean_s / 1e9, launches
            );
        }
    }

    batched_decode_sweep(&bench)?;

    // PJRT kernel artifacts
    if have_artifacts() {
        use fbquant::runtime::exec::Value;
        use fbquant::runtime::ExecRegistry;
        println!("\n=== PJRT kernel artifacts (m=32, k=n=512, r=64, interpret-lowered Pallas) ===");
        let mut reg = ExecRegistry::open(&artifacts())?;
        let mut rng = Pcg64::seeded(8);
        let (m, k, n, r) = (32usize, 512usize, 512usize, 64usize);
        let data = vec![
            Value::F32((0..m * k).map(|_| rng.normal() as f32).collect()),
            Value::I32((0..n * k).map(|_| rng.below(16) as i32).collect()),
            Value::F32((0..n * (k / 128)).map(|_| 0.02 + rng.next_f32() * 0.02).collect()),
            Value::F32((0..n * (k / 128)).map(|_| rng.below(16) as f32).collect()),
            Value::F32((0..r * k).map(|_| rng.normal() as f32 * 0.02).collect()),
            Value::F32((0..n * r).map(|_| rng.normal() as f32 * 0.02).collect()),
        ];
        for name in ["kernel_fused_m32", "kernel_unfused_m32"] {
            let exec = reg.load(name)?;
            let rb = bench.run(name, || {
                let _ = exec.run(&data, &[]).unwrap();
            });
            println!("{:<20} {:>10.2} ms/dispatch", name, rb.mean_ms());
        }
    }
    Ok(())
}
