//! Kernel-level microbenchmarks across the stack:
//! * rust native quantized GEMV/GEMM (fused / unfused / no-sub) across
//!   sizes, with effective bandwidth,
//! * dense FP GEMV for the roofline reference,
//! * the batched-decode sweep (slots × bits × rank): weight-stationary
//!   `gemv_multi` vs the per-slot loop, emitted to `BENCH_decode.json`
//!   (tokens/s + weight bytes/token) as the perf trajectory file CI
//!   smokes on every push,
//! * the {scalar, simd} × {scoped, pool} quadrant sweep on the fused
//!   kernel, emitted to `BENCH_kernels.json`, with a blocking
//!   SIMD+pool-beats-scalar+scoped assertion at the largest shape,
//! * the speculative sweep (K × draft-mode) on a synthesized
//!   checkpoint: acceptance rate, tokens/s, weight bytes per committed
//!   token and peak KV pages vs the K=0 baseline, with blocking
//!   assertions that the verifier's weight traffic is charged once per
//!   step regardless of K and that speculation's peak page footprint
//!   stays within 1.25× of a plain-decode twin at matched lengths
//!   (draft mirrors alias the shared pool),
//! * the sampled-speculation sweep: rejection-sampling acceptance vs
//!   temperature on a draft that genuinely differs from its target,
//! * the flight-recorder overhead gate: decode tokens/s with tracing
//!   off / request / kernel, blocking at 3% for the request level
//!   (emitted as the `tracing` block of `BENCH_decode.json`),
//! * the PJRT `kernel_fused`/`kernel_unfused` artifacts (the Pallas
//!   pair lowered by aot.py) — dispatch-count effect at the XLA level.

mod common;

use common::*;
use fbquant::bench::Bench;
use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken, SpecSlot};
use fbquant::coordinator::request::SamplingParams;
use fbquant::engine::kernels::{QuantLinear, SubMode, Traffic, Workspace};
use fbquant::engine::NativeEngine;
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::testing::{synth_checkpoint, SynthSpec};
use fbquant::util::json::Json;
use fbquant::util::Pcg64;
use std::time::Instant;

fn layer(d: usize, r: usize, bits: u8) -> (QuantLinear, Vec<f32>) {
    let mut rng = Pcg64::seeded(6);
    let w: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
    let p = groupwise::quant_params(&w, d, d, bits, 128.min(d));
    let codes = groupwise::quantize(&w, d, d, &p);
    (
        QuantLinear {
            out: d,
            cin: d,
            bits,
            group: 128.min(d),
            packed: pack_codes(&codes, d, d),
            scales: p.scales,
            zeros: p.zeros,
            rank: r,
            a: Some((0..r * d).map(|_| rng.normal() as f32 * 0.02).collect()),
            b: Some((0..d * r).map(|_| rng.normal() as f32 * 0.02).collect()),
            col_scale: None,
            bias: None,
        },
        w,
    )
}

/// Batched-decode sweep: the weight-stationary `gemv_multi` against the
/// per-slot `gemv` loop over slots × bits × rank, on one square decode
/// layer as the per-layer proxy. Emits `BENCH_decode.json` so the perf
/// trajectory (tokens/s, weight bytes/token) is tracked from CI; the
/// `kernel_matrix` section embeds the {scalar, simd} × {scoped, pool}
/// quadrant document ([`kernel_matrix_sweep`]).
fn batched_decode_sweep(
    bench: &Bench,
    spec_rows: Vec<Json>,
    kernel_matrix: Json,
    tracing: Json,
) -> anyhow::Result<()> {
    let d: usize = if fast() { 256 } else { 512 };
    let bits_list: &[u8] = if fast() { &[4] } else { &[3, 4] };
    let rank_list: &[usize] = &[0, 16];
    let slot_list: &[usize] = &[1, 2, 4, 8];

    println!(
        "\n=== batched decode sweep: weight-stationary gemv_multi vs per-slot gemv (d={d}) ==="
    );
    println!(
        "{:<5} {:<5} {:<5} {:<12} {:>11} {:>12} {:>13} {:>9}",
        "bits", "rank", "m", "impl", "latency(us)", "tokens/s", "W bytes/tok", "speedup"
    );
    println!("{}", "-".repeat(80));

    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Pcg64::seeded(9);
    for &bits in bits_list {
        for &rank in rank_list {
            let (mut ql, _) = layer(d, rank, bits);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            for &m in slot_list {
                let xs: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let mut ys = vec![0f32; m * d];
                let mut ws = Workspace::default();

                let mut results = Vec::new();
                for batched in [false, true] {
                    let mut t = Traffic::default();
                    if batched {
                        ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut t);
                    } else {
                        for i in 0..m {
                            ql.gemv(
                                &xs[i * d..(i + 1) * d],
                                &mut ys[i * d..(i + 1) * d],
                                SubMode::Fused,
                                &mut ws,
                                &mut t,
                            );
                        }
                    }
                    let wbpt = t.weight_bytes as f64 / m as f64;
                    let name = if batched { "batched" } else { "sequential" };
                    let r = bench.run(name, || {
                        let mut tt = Traffic::default();
                        if batched {
                            ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut tt);
                        } else {
                            for i in 0..m {
                                ql.gemv(
                                    &xs[i * d..(i + 1) * d],
                                    &mut ys[i * d..(i + 1) * d],
                                    SubMode::Fused,
                                    &mut ws,
                                    &mut tt,
                                );
                            }
                        }
                    });
                    let tps = m as f64 / r.min_s;
                    results.push((name, r.min_us(), tps, wbpt));
                }
                let speedup = results[1].2 / results[0].2;
                for (name, lat_us, tps, wbpt) in &results {
                    println!(
                        "{:<5} {:<5} {:<5} {:<12} {:>11.1} {:>12.0} {:>13.0} {:>9}",
                        bits,
                        rank,
                        m,
                        name,
                        lat_us,
                        tps,
                        wbpt,
                        if *name == "batched" { format!("{speedup:.2}x") } else { String::new() },
                    );
                    rows.push(Json::obj(vec![
                        ("d", Json::from(d)),
                        ("bits", Json::from(bits as usize)),
                        ("rank", Json::from(rank)),
                        ("slots", Json::from(m)),
                        ("impl", Json::from(*name)),
                        ("latency_us", Json::from(*lat_us)),
                        ("tokens_per_s", Json::from(*tps)),
                        ("weight_bytes_per_token", Json::from(*wbpt)),
                    ]));
                }
            }
        }
    }
    let n_rows = rows.len();
    let n_spec = spec_rows.len();
    let doc = Json::obj(vec![
        ("bench", Json::from("batched_decode_sweep")),
        ("unit", Json::from("per-layer decode proxy (one square quantized linear)")),
        ("rows", Json::Arr(rows)),
        ("speculative", Json::Arr(spec_rows)),
        ("kernel_matrix", kernel_matrix),
        ("tracing", tracing),
    ]);
    std::fs::write("BENCH_decode.json", doc.to_string_pretty())?;
    println!("\nwrote BENCH_decode.json ({n_rows} kernel rows + {n_spec} speculative rows)");
    Ok(())
}

/// Quadrant sweep {scalar, simd} × {scoped, pool} over bits × rank ×
/// slots on the fused weight-stationary kernel, emitted to
/// `BENCH_kernels.json` (schema_version 1) so the SIMD/pool perf
/// trajectory is tracked from CI. Every grid point carries exactly four
/// quadrant rows; the top-level `simd_available`/`simd_feature` flags
/// say whether the `simd` rows actually vectorized (forcing the simd
/// path without the feature or hardware falls back to scalar, so the
/// schema never changes shape across builds). When SIMD is live, the
/// largest m=8 shape must beat the scalar+scoped baseline on ns/MAC —
/// blocking, with one re-measure to de-noise — and the remaining m=8
/// points warn if they don't. Returns the emitted document so the same
/// quadrant rows also ride along inside `BENCH_decode.json`.
fn kernel_matrix_sweep(bench: &Bench) -> anyhow::Result<Json> {
    use fbquant::tensor::simd;
    use fbquant::util::pool;

    let d: usize = if fast() { 256 } else { 512 };
    let bits_list: &[u8] = if fast() { &[4] } else { &[2, 3, 4] };
    let rank_list: &[usize] = &[0, 16];
    let slot_list: &[usize] = &[1, 8];
    let simd_on = cfg!(feature = "simd") && simd::available();
    let overhead_ns = pool::global().dispatch_overhead_ns();
    let largest = (*bits_list.last().unwrap(), *rank_list.last().unwrap());

    println!(
        "\n=== kernel matrix sweep: {{scalar,simd}} x {{scoped,pool}} (d={d}, simd {}) ===",
        if simd_on { "on" } else { "off/fallback" }
    );
    println!(
        "{:<5} {:<5} {:<3} {:<14} {:>9} {:>11} {:>12} {:>9}",
        "bits", "rank", "m", "quadrant", "ns/MAC", "latency(us)", "tokens/s", "speedup"
    );
    println!("{}", "-".repeat(76));

    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Pcg64::seeded(13);
    for &bits in bits_list {
        for &rank in rank_list {
            let (mut ql, _) = layer(d, rank, bits);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            for &m in slot_list {
                let xs: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let mut ys = vec![0f32; m * d];
                let mut ws = Workspace::default();
                let macs = (m * d * d) as f64;
                let mut t = Traffic::default();
                ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut t);
                let wbpt = t.weight_bytes as f64 / m as f64;
                let mut measure = |path: simd::Path, disp: pool::Dispatch| -> f64 {
                    simd::force_path(Some(path));
                    pool::force_dispatch(Some(disp));
                    let r = bench.run("quadrant", || {
                        let mut tt = Traffic::default();
                        ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, &mut ws, &mut tt);
                    });
                    simd::force_path(None);
                    pool::force_dispatch(None);
                    r.min_s
                };
                let mut quad: Vec<(&str, &str, f64)> = Vec::new();
                for (pname, path) in [("scalar", simd::Path::Scalar), ("simd", simd::Path::Simd)] {
                    for (dname, disp) in
                        [("scoped", pool::Dispatch::Scoped), ("pool", pool::Dispatch::Pool)]
                    {
                        quad.push((pname, dname, measure(path, disp)));
                    }
                }
                // de-noise the two corner quadrants once before judging
                let base_s = quad[0].2.min(measure(simd::Path::Scalar, pool::Dispatch::Scoped));
                let best_s = quad[3].2.min(measure(simd::Path::Simd, pool::Dispatch::Pool));
                quad[0].2 = base_s;
                quad[3].2 = best_s;
                for &(pname, dname, min_s) in &quad {
                    let ns_mac = min_s * 1e9 / macs;
                    let lat_us = min_s * 1e6;
                    let tps = m as f64 / min_s;
                    let speed = base_s / min_s;
                    println!(
                        "{:<5} {:<5} {:<3} {:<14} {:>9.4} {:>11.1} {:>12.0} {:>8.2}x",
                        bits,
                        rank,
                        m,
                        format!("{pname}+{dname}"),
                        ns_mac,
                        lat_us,
                        tps,
                        speed
                    );
                    rows.push(Json::obj(vec![
                        ("d", Json::from(d)),
                        ("bits", Json::from(bits as usize)),
                        ("rank", Json::from(rank)),
                        ("slots", Json::from(m)),
                        ("path", Json::from(pname)),
                        ("dispatch", Json::from(dname)),
                        ("ns_per_mac", Json::from(ns_mac)),
                        ("latency_us", Json::from(lat_us)),
                        ("tokens_per_s", Json::from(tps)),
                        ("weight_bytes_per_token", Json::from(wbpt)),
                    ]));
                }
                if simd_on && m == 8 {
                    if (bits, rank) == largest {
                        assert!(
                            best_s < base_s,
                            "simd+pool ({:.4} ns/MAC) must beat scalar+scoped ({:.4} ns/MAC) \
                             at the largest shape bits={bits} rank={rank} m={m}",
                            best_s * 1e9 / macs,
                            base_s * 1e9 / macs
                        );
                    } else if best_s >= base_s {
                        eprintln!(
                            "warning: simd+pool did not beat scalar+scoped at bits={bits} \
                             rank={rank} m={m} ({:.4} vs {:.4} ns/MAC)",
                            best_s * 1e9 / macs,
                            base_s * 1e9 / macs
                        );
                    }
                }
            }
        }
    }
    let n_rows = rows.len();
    let doc = Json::obj(vec![
        ("bench", Json::from("kernel_matrix")),
        ("schema_version", Json::from(1usize)),
        ("unit", Json::from("fused weight-stationary gemv_multi, one square decode layer")),
        ("simd_feature", Json::from(cfg!(feature = "simd"))),
        ("simd_available", Json::from(simd::available())),
        ("threads", Json::from(pool::decode_threads())),
        ("pool_workers", Json::from(pool::global().workers())),
        ("pool_dispatch_overhead_ns", Json::from(overhead_ns as usize)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_kernels.json", doc.to_string_pretty())?;
    println!("\nwrote BENCH_kernels.json ({n_rows} quadrant rows)");
    Ok(doc)
}

/// End-to-end speculative sweep on a synthesized checkpoint: for each
/// draft mode and K, run a 4-slot greedy decode through the backend and
/// record acceptance rate, committed tokens/step, tokens/s and weight
/// bytes per committed token (target + draft) against the K=0 baseline.
/// Asserts — blocking in the CI smoke run — that the **verifier's**
/// weight traffic per step is identical across K: all K+1 positions ride
/// one weight-stationary pass, and that each speculative config's peak
/// KV pages stay within 1.25× of a plain-decode twin run to the same
/// per-slot lengths: draft mirrors alias the target's committed pages
/// in the unified pool, so speculation's only extra pages are the
/// boundary CoW copies and the verify reserve (the pre-unification
/// private draft pool paid ~2× here).
fn speculative_sweep(bench_fast: bool) -> anyhow::Result<Vec<Json>> {
    // sub_scale 0.0: the target pays the full sub-branch weight stream
    // (A/B are read) while contributing exactly nothing, so the bare
    // branch drafts the target's own chain — acceptance on the no-sub
    // rows is total by construction and the traffic effect is isolated
    // deterministically; the shadow rows show realistic partial
    // acceptance (2-bit grid vs 4-bit chain)
    let geom = SynthSpec {
        d: if bench_fast { 128 } else { 256 },
        d_ff: if bench_fast { 256 } else { 512 },
        vocab: 96,
        group: 32,
        rank: 8,
        sub_scale: 0.0,
        // headroom past the longest run (128-token prompt + 24 steps of
        // K=4): the worst-case pool is sized from max_seq, and the
        // draft's boundary CoW pages must never exhaust it at the tail
        // or the window degrades to plain decode
        max_seq: 384,
        ..SynthSpec::default()
    };
    let store = synth_checkpoint("bench_spec", geom);
    let decode_steps = if bench_fast { 12 } else { 24 };
    let m = 4usize;
    // Long enough that the KV-page gate below is sound in the worst
    // case: even at zero acceptance in the fast run the plain twin
    // peaks at ≥ 9 pages/slot, so the ≤ 2 extra pages/slot a window
    // can pin (one boundary CoW + one reserve page) stay under 1.25×.
    let plen = 128usize;

    println!(
        "\n=== speculative decode sweep: draft bare/shadow branch, batched multi-position verify \
         (d={}, {m} slots) ===",
        geom.d
    );
    println!(
        "{:<10} {:<3} {:>8} {:>9} {:>12} {:>13} {:>15} {:>9}",
        "draft", "K", "accept", "tok/step", "tokens/s", "W B/token", "verify W/step", "pk/plain"
    );
    println!("{}", "-".repeat(88));

    let mut rows: Vec<Json> = Vec::new();
    let mut target_weight_totals: Vec<(String, u64)> = Vec::new();
    let mut base_wbpt = 0f64;
    for (dname, draft) in [
        ("baseline", None),
        ("no-sub", Some(DraftMode::NoSub)),
        ("shadow2", Some(DraftMode::Shadow { bits: 2 })),
    ] {
        let k_list: &[usize] = if draft.is_none() { &[0] } else { &[1, 2, 4] };
        for &k in k_list {
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            let mut backend = NativeBackend::new(engine, "spec").with_max_slots(m);
            if let Some(dm) = draft {
                backend = backend.with_speculative(SpeculativeConfig::new(k, dm));
            }
            let mut state = backend.open_batch(m)?;
            let mut cur = vec![0u32; m];
            let mut lens = vec![plen; m];
            for slot in 0..m {
                let prompt: Vec<u32> =
                    (0..plen).map(|i| ((slot * 13 + i * 5) % 96) as u32).collect();
                let lg = backend.prefill_slot(&mut state, slot, &prompt)?;
                cur[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
            }
            backend.reset_traffic();
            let mut committed = 0usize;
            let mut proposed = 0usize;
            let mut accepted = 0usize;
            let t0 = Instant::now();
            for _ in 0..decode_steps {
                let toks: Vec<SlotToken> =
                    (0..m).map(|s| SlotToken { slot: s, token: cur[s] }).collect();
                if draft.is_some() {
                    let reqs: Vec<SpecSlot> =
                        (0..m).map(|s| SpecSlot::greedy(s, cur[s])).collect();
                    let steps = backend.decode_speculative(&mut state, &reqs)?;
                    for (slot, sp) in steps.iter().enumerate() {
                        committed += sp.accepted.len() + 1;
                        proposed += sp.proposed;
                        accepted += sp.accepted.len();
                        lens[slot] += sp.accepted.len() + 1;
                        cur[slot] = sp.next;
                    }
                } else {
                    let lg = backend.decode(&mut state, &toks)?;
                    for (slot, l) in lg.iter().enumerate() {
                        committed += 1;
                        lens[slot] += 1;
                        cur[slot] = fbquant::tensor::ops::argmax(l) as u32;
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let target_w = backend.traffic().weight_bytes;
            let draft_w = backend.draft_traffic().map_or(0, |t| t.weight_bytes);
            let wbpt = (target_w + draft_w) as f64 / committed as f64;
            let accept_rate =
                if proposed > 0 { accepted as f64 / proposed as f64 } else { 0.0 };
            let tok_per_step = committed as f64 / decode_steps as f64;
            let tps = committed as f64 / wall;
            let verify_w_step = target_w as f64 / decode_steps as f64;
            if draft.is_none() {
                base_wbpt = wbpt;
            }
            let peak_pages =
                backend.kv_stats(&state).expect("native backend is paged").peak_pages_in_use;
            // KV-page gate: replay the same prompts through plain decode
            // until every slot holds exactly as many tokens as this
            // config committed, and compare pool peaks. Draft mirrors
            // alias the target's committed pages in the unified pool,
            // so the only speculative surcharge is the boundary CoW
            // copy and the verify reserve — blocking at 1.25× of the
            // plain twin (a private draft pool would sit near 2×).
            let plain_peak = if draft.is_some() {
                let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
                let mut pb = NativeBackend::new(engine, "spec-plain").with_max_slots(m);
                let mut pstate = pb.open_batch(m)?;
                let mut pcur = vec![0u32; m];
                for slot in 0..m {
                    let prompt: Vec<u32> =
                        (0..plen).map(|i| ((slot * 13 + i * 5) % 96) as u32).collect();
                    let lg = pb.prefill_slot(&mut pstate, slot, &prompt)?;
                    pcur[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
                }
                let mut plens = vec![plen; m];
                while (0..m).any(|s| plens[s] < lens[s]) {
                    let toks: Vec<SlotToken> = (0..m)
                        .filter(|&s| plens[s] < lens[s])
                        .map(|s| SlotToken { slot: s, token: pcur[s] })
                        .collect();
                    let lg = pb.decode(&mut pstate, &toks)?;
                    for (t, l) in toks.iter().zip(lg.iter()) {
                        pcur[t.slot] = fbquant::tensor::ops::argmax(l) as u32;
                        plens[t.slot] += 1;
                    }
                }
                pb.kv_stats(&pstate).expect("native backend is paged").peak_pages_in_use
            } else {
                peak_pages
            };
            assert!(
                peak_pages as f64 <= 1.25 * plain_peak as f64,
                "{dname}/K{k}: speculative peak KV pages {peak_pages} exceed 1.25x the \
                 plain-decode peak {plain_peak} at the same slot count and lengths — the \
                 draft mirror is duplicating pages instead of aliasing them"
            );
            let pages_col = format!("{peak_pages}/{plain_peak}");
            println!(
                "{:<10} {:<3} {:>8.2} {:>9.2} {:>12.0} {:>13.0} {:>15.0} {:>9}",
                dname, k, accept_rate, tok_per_step, tps, wbpt, verify_w_step, pages_col
            );
            rows.push(Json::obj(vec![
                ("mode", Json::from("greedy")),
                ("temperature", Json::from(0.0f64)),
                ("draft", Json::from(dname)),
                ("k", Json::from(k)),
                ("slots", Json::from(m)),
                ("decode_steps", Json::from(decode_steps)),
                ("acceptance_rate", Json::from(accept_rate)),
                ("tokens_per_step", Json::from(tok_per_step)),
                ("tokens_per_s", Json::from(tps)),
                ("weight_bytes_per_token", Json::from(wbpt)),
                ("verify_weight_bytes_per_step", Json::from(verify_w_step)),
                ("peak_pages_in_use", Json::from(peak_pages)),
                ("plain_peak_pages", Json::from(plain_peak)),
            ]));
            target_weight_totals.push((format!("{dname}/K{k}"), target_w));
            // acceptance criterion: the no-sub rows accept everything on
            // this fixture (the bare branch drafts the target's own
            // chain), so mean acceptance is K ≥ 1 token/step and the
            // amortized weight stream must strictly beat the K=0
            // baseline — the draft skips the A/B read the target pays
            if matches!(draft, Some(DraftMode::NoSub)) {
                assert_eq!(
                    accepted, proposed,
                    "{dname}/K{k}: bare-branch drafts of a zero-sub model must all verify"
                );
                assert!(
                    wbpt < base_wbpt,
                    "{dname}/K{k}: weight bytes/token {wbpt:.0} not below the K=0 \
                     baseline {base_wbpt:.0} at acceptance {accept_rate:.2}"
                );
            } else if draft.is_some()
                && accepted as f64 / decode_steps as f64 >= 1.0
                && wbpt >= base_wbpt
            {
                eprintln!(
                    "warning: {dname}/K{k} at acceptance {accept_rate:.2} did not beat the \
                     baseline weight stream ({wbpt:.0} vs {base_wbpt:.0} B/token)"
                );
            }
        }
    }
    // the verifier streams its weights once per step no matter how many
    // draft positions ride along: every config ran the same step count,
    // so the target-side totals must be exactly equal
    let name0 = target_weight_totals[0].0.clone();
    let w0 = target_weight_totals[0].1;
    for (name, w) in &target_weight_totals {
        assert_eq!(
            *w, w0,
            "verifier weight traffic depends on K: {name} streamed {w} vs {name0} {w0}"
        );
    }
    println!(
        "\nverifier weight traffic: {} bytes/step for every config (charged once per step, \
         independent of K); draft stream is the only extra weight cost.",
        fbquant::util::human_bytes((w0 as usize) / decode_steps)
    );
    println!(
        "peak KV pages stayed within 1.25x of the plain-decode twin for every speculative \
         config: draft mirrors alias the shared pool instead of duplicating it."
    );
    Ok(rows)
}

/// Sampled speculation vs temperature: rejection-sampling acceptance on
/// a fixture whose draft genuinely differs from its target
/// (`sub_scale > 0`), at a fixed K over a temperature ladder. Emitted as
/// `mode: "sampled"` rows in the `speculative` section of
/// `BENCH_decode.json` so the acceptance-vs-temperature trajectory is
/// tracked alongside the greedy sweep. No monotonicity assertion — the
/// overlap `sum min(p, q)` need not move one way in temperature — but
/// the invariants (acceptance in [0, 1], every step commits >= 1 token)
/// are checked.
fn sampled_temperature_sweep(bench_fast: bool) -> anyhow::Result<Vec<Json>> {
    let geom = SynthSpec {
        d: if bench_fast { 64 } else { 128 },
        d_ff: if bench_fast { 96 } else { 256 },
        vocab: 96,
        group: 32,
        rank: 8,
        sub_scale: 0.25,
        max_seq: 256,
        ..SynthSpec::default()
    };
    let store = synth_checkpoint("bench_spec_sampled", geom);
    let decode_steps = if bench_fast { 16 } else { 32 };
    let (m, k, plen) = (4usize, 2usize, 16usize);

    println!(
        "\n=== sampled speculation vs temperature (no-sub draft, K={k}, {m} slots, \
         rejection-sampling acceptance) ==="
    );
    println!("{:<6} {:>8} {:>9} {:>12}", "temp", "accept", "tok/step", "tokens/s");
    println!("{}", "-".repeat(40));

    let mut rows: Vec<Json> = Vec::new();
    for &temp in &[0.4f32, 0.8, 1.2] {
        let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
        let mut backend = NativeBackend::new(engine, "spec-sampled")
            .with_max_slots(m)
            .with_speculative(SpeculativeConfig::new(k, DraftMode::NoSub));
        let mut state = backend.open_batch(m)?;
        let mut cur = vec![0u32; m];
        for slot in 0..m {
            let prompt: Vec<u32> = (0..plen).map(|i| ((slot * 17 + i * 3) % 96) as u32).collect();
            let lg = backend.prefill_slot(&mut state, slot, &prompt)?;
            cur[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
        }
        let (mut committed, mut proposed, mut accepted) = (0usize, 0usize, 0usize);
        let t0 = Instant::now();
        for step in 0..decode_steps {
            let reqs: Vec<SpecSlot> = (0..m)
                .map(|s| SpecSlot {
                    slot: s,
                    token: cur[s],
                    sampling: SamplingParams {
                        temperature: temp,
                        top_k: 0,
                        top_p: 1.0,
                        seed: 0x5eed ^ ((step as u64) << 8) ^ s as u64,
                    },
                })
                .collect();
            let steps = backend.decode_speculative(&mut state, &reqs)?;
            for (slot, sp) in steps.iter().enumerate() {
                assert!(sp.accepted.len() <= sp.proposed, "accepted more than proposed");
                committed += sp.accepted.len() + 1;
                proposed += sp.proposed;
                accepted += sp.accepted.len();
                cur[slot] = sp.next;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let accept_rate = if proposed > 0 { accepted as f64 / proposed as f64 } else { 0.0 };
        let tok_per_step = committed as f64 / decode_steps as f64;
        let tps = committed as f64 / wall;
        assert!(
            committed >= decode_steps * m,
            "every speculative step must commit at least the resampled token"
        );
        println!("{:<6.1} {:>8.2} {:>9.2} {:>12.0}", temp, accept_rate, tok_per_step, tps);
        rows.push(Json::obj(vec![
            ("mode", Json::from("sampled")),
            ("temperature", Json::from(temp as f64)),
            ("draft", Json::from("no-sub")),
            ("k", Json::from(k)),
            ("slots", Json::from(m)),
            ("decode_steps", Json::from(decode_steps)),
            ("acceptance_rate", Json::from(accept_rate)),
            ("tokens_per_step", Json::from(tok_per_step)),
            ("tokens_per_s", Json::from(tps)),
        ]));
    }
    Ok(rows)
}

/// Flight-recorder overhead on the decode hot loop: the same 4-slot
/// greedy decode measured with the recorder off, at request level, and
/// at kernel level — interleaved best-of-N so machine drift hits every
/// arm equally. Off vs request is the blocking 3% gate (request level is
/// the `FBQ_TRACE` default on the serving path, and every kernel site it
/// leaves disarmed costs a single relaxed load); kernel level actually
/// records ~4 events per layer per step and only warns, since it is the
/// documented heavier opt-in. Returns the `tracing` block that rides in
/// `BENCH_decode.json`.
fn tracing_overhead_sweep(bench_fast: bool) -> anyhow::Result<Json> {
    use fbquant::trace::{self, Level};

    let geom = SynthSpec {
        d: if bench_fast { 128 } else { 256 },
        d_ff: if bench_fast { 256 } else { 512 },
        vocab: 96,
        group: 32,
        rank: 8,
        max_seq: 256,
        ..SynthSpec::default()
    };
    let store = synth_checkpoint("bench_trace", geom);
    let decode_steps = if bench_fast { 16 } else { 32 };
    let (m, plen) = (4usize, 8usize);
    let rounds = if bench_fast { 3 } else { 5 };

    println!(
        "\n=== flight-recorder overhead: {m}-slot greedy decode, {decode_steps} steps, \
         best of {rounds} ==="
    );

    let mut measure = |level: Level| -> anyhow::Result<f64> {
        trace::set_level(level);
        let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
        let mut backend = NativeBackend::new(engine, "trace-bench").with_max_slots(m);
        let mut state = backend.open_batch(m)?;
        let mut cur = vec![0u32; m];
        for slot in 0..m {
            let prompt: Vec<u32> =
                (0..plen).map(|i| ((slot * 11 + i * 7) % 96) as u32).collect();
            let lg = backend.prefill_slot(&mut state, slot, &prompt)?;
            cur[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
        }
        let t0 = Instant::now();
        for _ in 0..decode_steps {
            let toks: Vec<SlotToken> =
                (0..m).map(|s| SlotToken { slot: s, token: cur[s] }).collect();
            let lg = backend.decode(&mut state, &toks)?;
            for (slot, l) in lg.iter().enumerate() {
                cur[slot] = fbquant::tensor::ops::argmax(l) as u32;
            }
        }
        let tps = (decode_steps * m) as f64 / t0.elapsed().as_secs_f64();
        trace::set_level(Level::Off);
        let _ = trace::drain(); // keep the rings from lapping across rounds
        Ok(tps)
    };

    let levels = [("off", Level::Off), ("request", Level::Request), ("kernel", Level::Kernel)];
    let mut best = [0f64; 3];
    for _ in 0..rounds {
        for (i, &(_, lvl)) in levels.iter().enumerate() {
            best[i] = best[i].max(measure(lvl)?);
        }
    }
    let [off_tps, req_tps, ker_tps] = best;
    for ((name, _), tps) in levels.iter().zip(best.iter()) {
        println!("{name:<8} {tps:>10.0} tokens/s ({:>6.2}% of off)", 100.0 * tps / off_tps);
    }
    assert!(
        req_tps >= 0.97 * off_tps,
        "request-level tracing cost the decode loop more than 3%: \
         {req_tps:.0} vs {off_tps:.0} tokens/s"
    );
    if ker_tps < 0.90 * off_tps {
        eprintln!(
            "warning: kernel-level tracing cost more than 10%: \
             {ker_tps:.0} vs {off_tps:.0} tokens/s"
        );
    }
    Ok(Json::obj(vec![
        (
            "unit",
            Json::from("4-slot greedy decode on a synthesized checkpoint, best-of-N tokens/s"),
        ),
        ("rounds", Json::from(rounds)),
        ("decode_steps", Json::from(decode_steps)),
        ("off_tokens_per_s", Json::from(off_tps)),
        ("request_tokens_per_s", Json::from(req_tps)),
        ("kernel_tokens_per_s", Json::from(ker_tps)),
        ("request_relative", Json::from(req_tps / off_tps)),
        ("kernel_relative", Json::from(ker_tps / off_tps)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let sizes: &[usize] = if fast() { &[256, 512] } else { &[256, 512, 1024] };
    let iters = if fast() { 3 } else { 8 };
    let bench = Bench::new(2, iters);

    println!("\n=== native kernel microbench: quantized GEMV (decode shape, m=1) ===");
    println!(
        "{:<6} {:<14} {:>11} {:>12} {:>10}",
        "d", "impl", "latency(us)", "GB/s eff.", "launches"
    );
    println!("{}", "-".repeat(58));
    for &d in sizes {
        let (ql, w) = layer(d, d / 32, 4);
        let mut rng = Pcg64::seeded(7);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0f32; d];
        let mut ws = Workspace::default();

        // dense reference
        let rd = bench.run("dense", || {
            for o in 0..d {
                y[o] = fbquant::tensor::ops::dot(&x, &w[o * d..(o + 1) * d]);
            }
        });
        println!(
            "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
            d, "FP32-dense", rd.mean_us(),
            (4 * d * d) as f64 / rd.mean_s / 1e9, 1
        );

        for (name, mode) in [
            ("INT4", SubMode::None),
            ("INT4-Sub", SubMode::Unfused),
            ("INT4-FBQuant", SubMode::Fused),
        ] {
            let mut t = Traffic::default();
            ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
            let bytes = t.total_bytes();
            let launches = t.kernel_launches;
            let r = bench.run(name, || {
                let mut tt = Traffic::default();
                ql.gemv(&x, &mut y, mode, &mut ws, &mut tt);
            });
            println!(
                "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
                d, name, r.mean_us(),
                bytes as f64 / r.mean_s / 1e9, launches
            );
        }
    }

    // the overhead gate runs first so its arms see a quiet process, and
    // leaves the recorder disarmed for the remaining sweeps
    let tracing = tracing_overhead_sweep(fast())?;
    let kernel_matrix = kernel_matrix_sweep(&bench)?;
    let mut spec_rows = speculative_sweep(fast())?;
    spec_rows.extend(sampled_temperature_sweep(fast())?);
    batched_decode_sweep(&bench, spec_rows, kernel_matrix, tracing)?;

    // PJRT kernel artifacts
    if have_artifacts() {
        use fbquant::runtime::exec::Value;
        use fbquant::runtime::ExecRegistry;
        println!("\n=== PJRT kernel artifacts (m=32, k=n=512, r=64, interpret-lowered Pallas) ===");
        let mut reg = ExecRegistry::open(&artifacts())?;
        let mut rng = Pcg64::seeded(8);
        let (m, k, n, r) = (32usize, 512usize, 512usize, 64usize);
        let data = vec![
            Value::F32((0..m * k).map(|_| rng.normal() as f32).collect()),
            Value::I32((0..n * k).map(|_| rng.below(16) as i32).collect()),
            Value::F32((0..n * (k / 128)).map(|_| 0.02 + rng.next_f32() * 0.02).collect()),
            Value::F32((0..n * (k / 128)).map(|_| rng.below(16) as f32).collect()),
            Value::F32((0..r * k).map(|_| rng.normal() as f32 * 0.02).collect()),
            Value::F32((0..n * r).map(|_| rng.normal() as f32 * 0.02).collect()),
        ];
        for name in ["kernel_fused_m32", "kernel_unfused_m32"] {
            let exec = reg.load(name)?;
            let rb = bench.run(name, || {
                let _ = exec.run(&data, &[]).unwrap();
            });
            println!("{:<20} {:>10.2} ms/dispatch", name, rb.mean_ms());
        }
    }
    Ok(())
}
