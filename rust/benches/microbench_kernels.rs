//! Kernel-level microbenchmarks across the stack:
//! * rust native quantized GEMV/GEMM (fused / unfused / no-sub) across
//!   sizes, with effective bandwidth,
//! * dense FP GEMV for the roofline reference,
//! * the PJRT `kernel_fused`/`kernel_unfused` artifacts (the Pallas
//!   pair lowered by aot.py) — dispatch-count effect at the XLA level.

mod common;

use common::*;
use fbquant::bench::Bench;
use fbquant::engine::kernels::{QuantLinear, SubMode, Traffic, Workspace};
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::util::Pcg64;

fn layer(d: usize, r: usize, bits: u8) -> (QuantLinear, Vec<f32>) {
    let mut rng = Pcg64::seeded(6);
    let w: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
    let p = groupwise::quant_params(&w, d, d, bits, 128.min(d));
    let codes = groupwise::quantize(&w, d, d, &p);
    (
        QuantLinear {
            out: d,
            cin: d,
            bits,
            group: 128.min(d),
            packed: pack_codes(&codes, d, d),
            scales: p.scales,
            zeros: p.zeros,
            rank: r,
            a: Some((0..r * d).map(|_| rng.normal() as f32 * 0.02).collect()),
            b: Some((0..d * r).map(|_| rng.normal() as f32 * 0.02).collect()),
            col_scale: None,
            bias: None,
        },
        w,
    )
}

fn main() -> anyhow::Result<()> {
    let sizes: &[usize] = if fast() { &[256, 512] } else { &[256, 512, 1024] };
    let iters = if fast() { 3 } else { 8 };
    let bench = Bench::new(2, iters);

    println!("\n=== native kernel microbench: quantized GEMV (decode shape, m=1) ===");
    println!(
        "{:<6} {:<14} {:>11} {:>12} {:>10}",
        "d", "impl", "latency(us)", "GB/s eff.", "launches"
    );
    println!("{}", "-".repeat(58));
    for &d in sizes {
        let (ql, w) = layer(d, d / 32, 4);
        let mut rng = Pcg64::seeded(7);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0f32; d];
        let mut ws = Workspace::default();

        // dense reference
        let rd = bench.run("dense", || {
            for o in 0..d {
                y[o] = fbquant::tensor::ops::dot(&x, &w[o * d..(o + 1) * d]);
            }
        });
        println!(
            "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
            d, "FP32-dense", rd.mean_us(),
            (4 * d * d) as f64 / rd.mean_s / 1e9, 1
        );

        for (name, mode) in [
            ("INT4", SubMode::None),
            ("INT4-Sub", SubMode::Unfused),
            ("INT4-FBQuant", SubMode::Fused),
        ] {
            let mut t = Traffic::default();
            ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
            let bytes = t.total_bytes();
            let launches = t.kernel_launches;
            let r = bench.run(name, || {
                let mut tt = Traffic::default();
                ql.gemv(&x, &mut y, mode, &mut ws, &mut tt);
            });
            println!(
                "{:<6} {:<14} {:>11.1} {:>12.2} {:>10}",
                d, name, r.mean_us(),
                bytes as f64 / r.mean_s / 1e9, launches
            );
        }
    }

    // PJRT kernel artifacts
    if have_artifacts() {
        use fbquant::runtime::exec::Value;
        use fbquant::runtime::ExecRegistry;
        println!("\n=== PJRT kernel artifacts (m=32, k=n=512, r=64, interpret-lowered Pallas) ===");
        let mut reg = ExecRegistry::open(&artifacts())?;
        let mut rng = Pcg64::seeded(8);
        let (m, k, n, r) = (32usize, 512usize, 512usize, 64usize);
        let data = vec![
            Value::F32((0..m * k).map(|_| rng.normal() as f32).collect()),
            Value::I32((0..n * k).map(|_| rng.below(16) as i32).collect()),
            Value::F32((0..n * (k / 128)).map(|_| 0.02 + rng.next_f32() * 0.02).collect()),
            Value::F32((0..n * (k / 128)).map(|_| rng.below(16) as f32).collect()),
            Value::F32((0..r * k).map(|_| rng.normal() as f32 * 0.02).collect()),
            Value::F32((0..n * r).map(|_| rng.normal() as f32 * 0.02).collect()),
        ];
        for name in ["kernel_fused_m32", "kernel_unfused_m32"] {
            let exec = reg.load(name)?;
            let rb = bench.run(name, || {
                let _ = exec.run(&data, &[]).unwrap();
            });
            println!("{:<20} {:>10.2} ms/dispatch", name, rb.mean_ms());
        }
    }
    Ok(())
}
