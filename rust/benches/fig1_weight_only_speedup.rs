//! Fig. 1: impact of weight-only quantization — end-to-end time (prefill +
//! decode) and resident weight memory, FP vs INT4.
//!
//! Paper shape (RTX 3090, Llama2-7B): INT4 runs prefill-1024 + decode-80
//! in ~60% of FP16's time and uses ~25% of the memory. Our substrate is
//! FP32 (no f16 kernels on this CPU), so the analytic memory ratio is
//! ~1/8 for codes (reported both measured and FP16-normalised).

mod common;

use common::*;
use fbquant::bench::Bench;
use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;

fn run_case(model: &str, method: &str, bits: u8, mode: SubMode,
            prompt: &[u32], decode: usize) -> anyhow::Result<(f64, usize, f64)> {
    let store = WeightStore::load(&ckpt(model, method, bits))?;
    let engine = NativeEngine::from_store(&store, mode)?;
    let bytes = engine.resident_bytes();
    let mut backend = NativeBackend::new(engine, model);
    let bench = Bench::new(1, if fast() { 2 } else { 4 });
    let r = bench.run(method, || {
        backend.reset_traffic();
        let mut state = backend.open_batch(1).unwrap();
        let logits = backend.prefill_slot(&mut state, 0, prompt).unwrap();
        let mut tok = fbquant::tensor::ops::argmax(&logits) as u32;
        for _ in 0..decode {
            let lg = backend.decode(&mut state, &[SlotToken { slot: 0, token: tok }]).unwrap();
            tok = fbquant::tensor::ops::argmax(&lg[0]) as u32;
        }
    });
    let run_bytes = backend.traffic().total_bytes() as f64;
    Ok((r.min_s, bytes, run_bytes))
}

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("fig1: run `make artifacts` first");
        return Ok(());
    }
    let model = if fast() { "llamoid-tiny" } else { "llamoid-small" };
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let prompt: Vec<u32> = stream.tokens()[..128].iter().map(|&b| b as u32).collect();
    let decode = 80;

    println!(
        "\n=== Fig 1: weight-only quantization impact \
         ({model}, prefill {} + decode {decode}) ===",
        prompt.len()
    );
    let (t_fp, b_fp, traffic_fp) = run_case(model, "fp", 4, SubMode::None, &prompt, decode)?;
    let (t_q, b_q, traffic_q) = run_case(model, "rtn", 4, SubMode::None, &prompt, decode)?;

    // projection to the paper's weight-bandwidth-bound regime (20 GB/s)
    let proj_fp = traffic_fp / 20e9;
    let proj_q = traffic_q / 20e9;

    println!(
        "{:<8} {:>12} {:>8} {:>13} {:>8} {:>14} {:>8}",
        "Weights", "latency(ms)", "norm.", "proj.(ms)*", "norm.", "memory", "norm."
    );
    println!("{}", "-".repeat(80));
    println!(
        "{:<8} {:>12.1} {:>8.2} {:>13.1} {:>8.2} {:>14} {:>8.2}",
        "FP32", t_fp * 1e3, 1.0, proj_fp * 1e3, 1.0,
        fbquant::util::human_bytes(b_fp), 1.0
    );
    println!(
        "{:<8} {:>12.1} {:>8.2} {:>13.1} {:>8.2} {:>14} {:>8.2}",
        "INT4", t_q * 1e3, t_q / t_fp, proj_q * 1e3, proj_q / proj_fp,
        fbquant::util::human_bytes(b_q), b_q as f64 / b_fp as f64
    );
    println!(
        "\n*projected from measured kernel traffic on a 20 GB/s memory-bound device\n\
         (the paper's regime: 7B weights >> cache; our toy weights are cache-resident,\n\
         so the measured column is compute-bound — see EXPERIMENTS.md).\n\
         paper (FP16 baseline): INT4 time ≈ 0.60×, memory ≈ 0.25×.\n\
         ours: projected time {:.2}×, memory {:.2}× (≈ {:.2}× vs an FP16 baseline —\n\
         embeddings/norms stay float at this toy scale).",
        proj_q / proj_fp,
        b_q as f64 / b_fp as f64,
        2.0 * b_q as f64 / b_fp as f64
    );
    Ok(())
}
