//! Fig. 4: MACs vs latency of the sub-branch — the motivation for §4.3.
//!
//! Paper shape (Llama2-7B linear layer, r=128, d=4096): the sub-branch
//! adds 6.25% MACs but ~20% prefill latency and up to 4× decode latency
//! when implemented naively; FBQuant's fusion recovers most of it.
//!
//! We report (a) measured wall-clock on the rust native kernels at a
//! CPU-scale layer, (b) the byte-traffic/launch counters, and (c) the
//! paper-scale analytic roofline model (mirroring
//! `python/compile/kernels/traffic.py`).

mod common;

use common::*;
use fbquant::engine::kernels::{QuantLinear, SubMode, Traffic, Workspace};
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::util::Pcg64;

fn make_layer(d: usize, r: usize) -> QuantLinear {
    let mut rng = Pcg64::seeded(4);
    let w: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
    let p = groupwise::quant_params(&w, d, d, 4, 128);
    let codes = groupwise::quantize(&w, d, d, &p);
    QuantLinear {
        out: d,
        cin: d,
        bits: 4,
        group: 128,
        packed: pack_codes(&codes, d, d),
        scales: p.scales,
        zeros: p.zeros,
        rank: r,
        a: Some((0..r * d).map(|_| rng.normal() as f32 * 0.02).collect()),
        b: Some((0..d * r).map(|_| rng.normal() as f32 * 0.02).collect()),
        col_scale: None,
        bias: None,
    }
}

const MODES: [SubMode; 3] = [SubMode::None, SubMode::Unfused, SubMode::Fused];

/// Measure all three modes interleaved round-robin, taking the per-mode
/// minimum: robust to scheduler steal-time and clock ramping on this
/// shared single vCPU (a sequential per-mode loop systematically penalises
/// whichever mode runs first).
fn measure_all(ql: &QuantLinear, m: usize, rounds: usize) -> Vec<(f64, Traffic)> {
    let mut ws = Workspace::default();
    let mut rng = Pcg64::seeded(5);
    let x: Vec<f32> = (0..m * ql.cin).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0f32; m * ql.out];

    let mut run = |mode: SubMode| -> Traffic {
        let mut t = Traffic::default();
        if m == 1 {
            ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
        } else {
            ql.gemm(&x, m, &mut y, mode, &mut ws, &mut t);
        }
        t
    };
    // warmup + traffic capture
    let traffic: Vec<Traffic> = MODES.iter().map(|&mode| run(mode)).collect();
    let mut best = [f64::INFINITY; 3];
    for _ in 0..rounds {
        for (i, &mode) in MODES.iter().enumerate() {
            let t0 = std::time::Instant::now();
            let _ = run(mode);
            best[i] = best[i].min(t0.elapsed().as_secs_f64());
        }
    }
    best.iter().zip(traffic).map(|(&t, tr)| (t, tr)).collect()
}

fn main() -> anyhow::Result<()> {
    let d = if fast() { 512 } else { 1024 };
    let r = d / 32; // the paper's r/d ratio (128/4096)
    let ql = make_layer(d, r);
    let iters = if fast() { 3 } else { 10 };

    let macs_main = d as f64 * d as f64;
    let macs_sub = 2.0 * r as f64 * d as f64;
    println!("\n=== Fig 4: sub-branch MACs vs latency (d={d}, r={r}, INT4 g128) ===");
    println!("MACs overhead of sub-branch: {:.2}% (paper: 6.25%)", 100.0 * macs_sub / macs_main);

    for (phase, m) in [("decode (m=1)", 1usize), ("prefill (m=128)", 128)] {
        let rounds = if m == 1 { iters * 8 } else { iters };
        let results = measure_all(&ql, m, rounds);
        let (t_plain, tr_plain) = (results[0].0, results[0].1.clone());
        let (t_naive, tr_naive) = (results[1].0, results[1].1.clone());
        let (t_fused, tr_fused) = (results[2].0, results[2].1.clone());
        println!("\n[{phase}] (normalised to plain INT4)");
        println!(
            "{:<14} {:>11} {:>8} {:>10} {:>9}",
            "impl", "latency(us)", "norm.", "bytes", "launches"
        );
        for (name, t, tr) in [
            ("INT4", t_plain, &tr_plain),
            ("INT4-Sub", t_naive, &tr_naive),
            ("INT4-FBQuant", t_fused, &tr_fused),
        ] {
            println!(
                "{:<14} {:>11.1} {:>8.2} {:>10} {:>9}",
                name,
                t * 1e6,
                t / t_plain,
                fbquant::util::human_bytes(tr.total_bytes() as usize),
                tr.kernel_launches
            );
        }
        let extra_naive = t_naive - t_plain;
        let extra_fused = t_fused - t_plain;
        if extra_naive > 0.0 {
            println!(
                "extra latency saved by fusion: {:.0}% (paper: ~60%)",
                100.0 * (1.0 - extra_fused / extra_naive)
            );
        }
    }

    // paper-scale analytic model (RTX-3090-class roofline, d=4096, r=128)
    println!("\n[analytic roofline, paper scale d=4096 r=128 — see kernels/traffic.py]");
    for (phase, m) in [("prefill (m=1024)", 1024usize), ("decode (m=1)", 1)] {
        let (k, n, rr) = (4096f64, 4096f64, 128f64);
        let bw = 936e9f64;
        let flops = 35e12f64;
        let launch = 4e-6f64;
        let cost = |bytes: f64, fl: f64| launch + (bytes / bw).max(fl / flops);
        let w_bytes = k * n * 0.5 + 8.0 * n * (k / 128.0);
        let mf = m as f64;
        let base = cost(2.0 * mf * k + w_bytes + 2.0 * mf * n, 2.0 * mf * k * n);
        let naive = cost(w_bytes + 2.0 * k * n, k * n)
            + cost(2.0 * mf * k + 2.0 * k * n + 2.0 * mf * n, 2.0 * mf * k * n)
            + cost(2.0 * mf * k + 2.0 * rr * k + 4.0 * mf * rr, 2.0 * mf * k * rr)
            + cost(4.0 * mf * n + 4.0 * mf * rr + 2.0 * n * rr, 2.0 * mf * rr * n);
        let fused = cost(2.0 * mf * k + 2.0 * rr * k + 4.0 * mf * rr, 2.0 * mf * k * rr)
            + cost(2.0 * mf * k + w_bytes + 4.0 * mf * rr + 2.0 * n * rr + 2.0 * mf * n,
                   2.0 * mf * k * n + 2.0 * mf * rr * n);
        println!(
            "  {phase:<18} INT4=1.00  INT4-Sub={:.2}  INT4-FBQuant={:.2}  (saved {:.0}%)",
            naive / base,
            fused / base,
            100.0 * (1.0 - (fused - base) / (naive - base))
        );
    }
    Ok(())
}
