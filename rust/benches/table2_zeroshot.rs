//! Table 2 (+ Tables 3–8 detail): zero-shot multiple-choice accuracy over
//! the seven task suites, lm-eval style.
//!
//! Default runs the full model grid with a question subset; pass
//! `--detail` style env `FBQ_BENCH_DETAIL=1` for per-task rows (the
//! appendix tables) and `FBQ_BENCH_FULL=1` for all 80 questions.

mod common;

use common::*;
use fbquant::eval::data::McTask;
use fbquant::eval::zeroshot::eval_suite;

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("table2_zeroshot: run `make artifacts` first");
        return Ok(());
    }
    let tasks = McTask::load_all(&artifacts().join("data"))?;
    let full = std::env::var("FBQ_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let detail = std::env::var("FBQ_BENCH_DETAIL").map(|v| v == "1").unwrap_or(false) || full;
    let maxq = if full {
        80
    } else if fast() {
        10
    } else {
        15
    };
    // full-grid zero-shot is expensive on one core: default to the tiny
    // family; FBQ_BENCH_FULL=1 runs all six models at 80 questions
    let models: Vec<&str> = if full {
        MODELS.to_vec()
    } else if fast() {
        vec!["llamoid-tiny"]
    } else {
        vec!["llamoid-tiny", "qwenoid-tiny", "gptoid-tiny"]
    };

    println!(
        "\n=== Table 2: zero-shot accuracy, avg over {} tasks (higher is better) ===",
        tasks.len()
    );
    println!("(questions/task={maxq}; length-normalised log-likelihood scoring)");
    let mut header = format!("{:<10} {:>5}", "Method", "WBit");
    for m in &models {
        header.push_str(&format!(" {:>14}", m));
    }
    println!("{header}");
    println!("{}", "-".repeat(header.len()));

    let mut rows: Vec<(String, u8)> = vec![("fp".into(), 16)];
    for &bits in &[4u8, 3] {
        for &m in METHODS {
            rows.push((m.into(), bits));
        }
    }

    for (method, bits) in rows {
        let mut line = format!("{:<10} {:>5}", method, bits);
        let mut details = Vec::new();
        for model in &models {
            match native_scorer(model, &method, bits) {
                Ok(mut scorer) => {
                    let (results, avg) = eval_suite(&mut scorer, &tasks, maxq)?;
                    line.push_str(&format!(" {:>13.2}%", 100.0 * avg));
                    details.push((model.to_string(), results));
                }
                Err(_) => line.push_str(&format!(" {:>14}", "-")),
            }
        }
        println!("{line}");
        if detail {
            for (model, results) in details {
                let cells: Vec<String> = results
                    .iter()
                    .map(|r| format!("{}={:.1}%", r.task, 100.0 * r.accuracy()))
                    .collect();
                println!("    [{model}] {}", cells.join(" "));
            }
        }
    }
    Ok(())
}
