//! Fig. 7: token throughput (tk/s), batch 1 — FP vs INT4 vs INT4-Sub
//! (naive sub-branch) vs INT4-FBQuant (fused).
//!
//! Paper shape (Llama2-7B, RTX 3090, prefill 256 / decode 64):
//! FP16 ≈ 48 tk/s, INT4-Sub ≈ 46 tk/s (sub-branch eats the quant win),
//! INT4-FBQuant ≈ 61 tk/s, plain INT4 fastest.
//!
//! Ours: prefill 192 / decode 64 (max_seq 256 at toy scale), rust native
//! engine, end-to-end through the coordinator.

mod common;

use common::*;
use fbquant::coordinator::backend::{Backend, NativeBackend};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;
use std::time::Instant;

fn throughput(model: &str, method: &str, bits: u8, mode: SubMode,
              prompt: &[u32], decode: usize, reps: usize) -> anyhow::Result<(f64, f64, f64)> {
    let store = WeightStore::load(&ckpt(model, method, bits))?;
    let engine = NativeEngine::from_store(&store, mode)?;
    let mut backend = NativeBackend::new(engine, model);
    // warmup
    let (mut state, logits) = backend.prefill(&[prompt], 1)?;
    let mut tok = fbquant::tensor::ops::argmax(&logits[0]) as u32;
    let _ = backend.decode(&mut state, &[tok])?;
    drop(state);

    let mut best_decode_tps = 0f64;
    let mut best_e2e_tps = 0f64;
    let mut bytes_per_tok = 0f64;
    for _ in 0..reps {
        let t0 = Instant::now();
        let (mut state, logits) = backend.prefill(&[prompt], 1)?;
        let t_prefill = t0.elapsed().as_secs_f64();
        tok = fbquant::tensor::ops::argmax(&logits[0]) as u32;
        backend.reset_traffic();
        let td = Instant::now();
        for _ in 0..decode {
            let lg = backend.decode(&mut state, &[tok])?;
            tok = fbquant::tensor::ops::argmax(&lg[0]) as u32;
        }
        let t_decode = td.elapsed().as_secs_f64();
        bytes_per_tok = backend.traffic().total_bytes() as f64 / decode as f64;
        // best-of-reps: robust to steal-time on a shared vCPU
        best_decode_tps = best_decode_tps.max(decode as f64 / t_decode);
        best_e2e_tps =
            best_e2e_tps.max((prompt.len() + decode) as f64 / (t_prefill + t_decode));
    }
    Ok((best_decode_tps, best_e2e_tps, bytes_per_tok))
}

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("fig7: run `make artifacts` first");
        return Ok(());
    }
    let model = if fast() { "llamoid-tiny" } else { "llamoid-small" };
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let prompt: Vec<u32> = stream.tokens()[..192].iter().map(|&b| b as u32).collect();
    let decode = 64;
    let reps = if fast() { 2 } else { 4 };

    println!("\n=== Fig 7: token throughput ({model}, prefill {} + decode {decode}, batch 1) ===",
             prompt.len());
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>12} {:>12}",
        "impl", "decode tk/s", "e2e tk/s", "norm.", "bytes/tok", "proj. tk/s*"
    );
    println!("{}", "-".repeat(76));

    let cases: Vec<(&str, &str, u8, SubMode)> = vec![
        ("FP32", "fp", 4, SubMode::None),
        ("INT4", "rtn", 4, SubMode::None),
        ("INT4-Sub", "fbquant", 4, SubMode::Unfused),
        ("INT4-FBQuant", "fbquant", 4, SubMode::Fused),
    ];
    // projection: a weight-bandwidth-bound edge device at 20 GB/s (the
    // paper's regime — our toy weights are cache-resident on CPU, so the
    // measured FP-vs-INT4 column is compute-bound; see EXPERIMENTS.md)
    const EDGE_BW: f64 = 20e9;
    let mut fp_tps = 0f64;
    for (name, method, bits, mode) in cases {
        let (dtps, etps, bpt) = throughput(model, method, bits, mode, &prompt, decode, reps)?;
        if name == "FP32" {
            fp_tps = dtps;
        }
        println!(
            "{:<14} {:>12.1} {:>12.1} {:>8.2} {:>12} {:>12.1}",
            name,
            dtps,
            etps,
            dtps / fp_tps,
            fbquant::util::human_bytes(bpt as usize),
            EDGE_BW / bpt
        );
    }
    println!("\n*projected decode tk/s on a 20 GB/s memory-bound edge device (bytes/token");
    println!(" measured from the kernel traffic counters — the regime of the paper's Fig 7).");
    println!("paper (3090, Llama2-7B): FP16 48 tk/s, INT4-Sub 46, INT4 ~64, INT4-FBQuant 61.");
    Ok(())
}
