//! Fig. 7: token throughput (tk/s), batch 1 — FP vs INT4 vs INT4-Sub
//! (naive sub-branch) vs INT4-FBQuant (fused) — plus the serving-side
//! comparisons the quantization exists for: weight-stationary batched vs
//! per-slot sequential decode at equal slot count, continuous (slot-pool)
//! vs batch-synchronous scheduling, paged vs dense KV at an equal memory
//! budget, prompt-prefix reuse on a templated workload, and
//! self-speculative decoding (bare-branch drafts, batched multi-position
//! verify) vs plain decode on the same greedy workload.
//!
//! Paper shape (Llama2-7B, RTX 3090, prefill 256 / decode 64):
//! FP16 ≈ 48 tk/s, INT4-Sub ≈ 46 tk/s (sub-branch eats the quant win),
//! INT4-FBQuant ≈ 61 tk/s, plain INT4 fastest.
//!
//! Ours: prefill 192 / decode 64 (max_seq 256 at toy scale), rust native
//! engine, end-to-end through the coordinator.

mod common;

use common::*;
use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
use fbquant::coordinator::request::{GenRequest, SamplingParams};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::util::Pcg64;
use std::time::Instant;

fn throughput(model: &str, method: &str, bits: u8, mode: SubMode,
              prompt: &[u32], decode: usize, reps: usize) -> anyhow::Result<(f64, f64, f64)> {
    let store = WeightStore::load(&ckpt(model, method, bits))?;
    let engine = NativeEngine::from_store(&store, mode)?;
    let mut backend = NativeBackend::new(engine, model);
    // warmup
    let mut state = backend.open_batch(1)?;
    let logits = backend.prefill_slot(&mut state, 0, prompt)?;
    let mut tok = fbquant::tensor::ops::argmax(&logits) as u32;
    let _ = backend.decode(&mut state, &[SlotToken { slot: 0, token: tok }])?;
    drop(state);

    let mut best_decode_tps = 0f64;
    let mut best_e2e_tps = 0f64;
    let mut bytes_per_tok = 0f64;
    for _ in 0..reps {
        let t0 = Instant::now();
        let mut state = backend.open_batch(1)?;
        let logits = backend.prefill_slot(&mut state, 0, prompt)?;
        let t_prefill = t0.elapsed().as_secs_f64();
        tok = fbquant::tensor::ops::argmax(&logits) as u32;
        backend.reset_traffic();
        let td = Instant::now();
        for _ in 0..decode {
            let lg = backend.decode(&mut state, &[SlotToken { slot: 0, token: tok }])?;
            tok = fbquant::tensor::ops::argmax(&lg[0]) as u32;
        }
        let t_decode = td.elapsed().as_secs_f64();
        bytes_per_tok = backend.traffic().total_bytes() as f64 / decode as f64;
        // best-of-reps: robust to steal-time on a shared vCPU
        best_decode_tps = best_decode_tps.max(decode as f64 / t_decode);
        best_e2e_tps =
            best_e2e_tps.max((prompt.len() + decode) as f64 / (t_prefill + t_decode));
    }
    Ok((best_decode_tps, best_e2e_tps, bytes_per_tok))
}

/// Mixed-length closed-loop workload: prompts of several lengths, varied
/// generation budgets, all queued at t=0.
fn serving_workload(stream: &TokenStream, n: usize) -> Vec<GenRequest> {
    let mut rng = Pcg64::seeded(0x51077);
    let toks = stream.tokens();
    let lens = [16usize, 32, 64];
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n {
        let plen = lens[rng.below(lens.len())];
        let start = rng.below(toks.len().saturating_sub(plen + 1));
        let prompt: Vec<u32> = toks[start..start + plen].iter().map(|&b| b as u32).collect();
        // 8..=40 generated tokens: uneven finish times are what the
        // continuous scheduler exploits
        let gen = 8 + rng.below(33);
        let mut req = GenRequest::new(i as u64 + 1, prompt, gen);
        req.params = SamplingParams::default();
        reqs.push(req);
    }
    reqs
}

/// Continuous vs batch-synchronous serving through the coordinator: same
/// backend, same workload, only the scheduling discipline differs.
fn serving_comparison(model: &str, stream: &TokenStream, n: usize) -> anyhow::Result<()> {
    println!(
        "\n=== serving: continuous (slot pool) vs batch-synchronous \
         ({model}, {n} reqs, mixed 16/32/64-token prompts) ==="
    );
    println!(
        "{:<14} {:>9} {:>10} {:>10} {:>7} {:>16} {:>13} {:>13}",
        "scheduler", "gen toks", "wall s", "gen tk/s", "occup.", "occupancy hist",
        "ttft p50 ms", "e2e p95 ms"
    );
    println!("{}", "-".repeat(98));
    let store = WeightStore::load(&ckpt(model, "fbquant", 4))?;
    let mut results = Vec::new();
    for (label, continuous) in [("continuous", true), ("batch-sync", false)] {
        let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
        let mut backend = NativeBackend::new(engine, label);
        let cfg = CoordinatorConfig { continuous, ..CoordinatorConfig::default() };
        let reqs = serving_workload(stream, n);
        let expect: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
        let t0 = Instant::now();
        let (responses, metrics) = Coordinator::run_closed_loop(&mut backend, reqs, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len(), n, "lost requests");
        assert_eq!(metrics.tokens_generated, expect, "lost tokens");
        println!(
            "{:<14} {:>9} {:>10.2} {:>10.1} {:>7.2} {:>16} {:>13.1} {:>13.1}",
            label,
            metrics.tokens_generated,
            wall,
            metrics.tokens_generated as f64 / wall,
            metrics.mean_slot_occupancy(),
            metrics.occupancy_histogram(),
            metrics.ttft.percentile_us(50.0) / 1e3,
            metrics.e2e.percentile_us(95.0) / 1e3,
        );
        let tps = metrics.tokens_generated as f64 / wall;
        results.push((label, metrics.mean_slot_occupancy(), tps));
    }
    let (_, cont_occ, cont_tps) = results[0];
    let (_, sync_occ, sync_tps) = results[1];
    println!(
        "\ncontinuous sustains {:.2}x the decode-slot occupancy ({:.2} vs {:.2}) \
         at {:.2}x tokens/s ({:.1} vs {:.1});",
        cont_occ / sync_occ.max(1e-9), cont_occ, sync_occ,
        cont_tps / sync_tps.max(1e-9), cont_tps, sync_tps,
    );
    println!("with the weight-stationary batched decode the native engine streams the weights");
    println!("once per step across all occupied slots, so the occupancy gap is a tokens/s gap.");
    Ok(())
}

/// Batched (weight-stationary) vs per-slot sequential decode at **equal
/// slot count**: same admitted prompts, same greedy continuations (the
/// two paths are bit-identical), only the decode kernel strategy — and
/// with it the per-step weight traffic — differs.
fn batched_vs_sequential(model: &str, stream: &TokenStream) -> anyhow::Result<()> {
    let store = WeightStore::load(&ckpt(model, "fbquant", 4))?;
    let toks = stream.tokens();
    let plen = 24usize;
    let decode = if fast() { 24 } else { 48 };
    let reps = 2;

    println!(
        "\n=== decode: weight-stationary batched vs per-slot sequential \
         ({model}, equal slot count) ==="
    );
    println!(
        "{:<6} {:<12} {:>10} {:>13} {:>9}",
        "slots", "decode", "gen tk/s", "W bytes/tok", "speedup"
    );
    println!("{}", "-".repeat(54));
    for &m in &[1usize, 2, 4, 8] {
        let mut row: Vec<(f64, f64)> = Vec::new();
        for batched in [false, true] {
            let mut best_tps = 0f64;
            let mut wbpt = 0f64;
            for _ in 0..reps {
                let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
                let mut backend = NativeBackend::new(engine, "bd").with_max_slots(m);
                if !batched {
                    backend = backend.with_sequential_decode();
                }
                let mut state = backend.open_batch(m)?;
                let mut last = vec![0u32; m];
                for slot in 0..m {
                    let start = (slot * 137) % (toks.len() - plen - 1);
                    let prompt: Vec<u32> =
                        toks[start..start + plen].iter().map(|&b| b as u32).collect();
                    let lg = backend.prefill_slot(&mut state, slot, &prompt)?;
                    last[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
                }
                backend.reset_traffic();
                let t0 = Instant::now();
                for _ in 0..decode {
                    let st: Vec<SlotToken> =
                        (0..m).map(|s| SlotToken { slot: s, token: last[s] }).collect();
                    let lg = backend.decode(&mut state, &st)?;
                    for (s, l) in lg.iter().enumerate() {
                        last[s] = fbquant::tensor::ops::argmax(l) as u32;
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                best_tps = best_tps.max((m * decode) as f64 / wall);
                wbpt = backend.traffic().weight_bytes as f64 / (m * decode) as f64;
            }
            println!(
                "{:<6} {:<12} {:>10.1} {:>13} {:>9}",
                m,
                if batched { "batched" } else { "sequential" },
                best_tps,
                fbquant::util::human_bytes(wbpt as usize),
                if batched && !row.is_empty() {
                    format!("{:.2}x", best_tps / row[0].0)
                } else {
                    String::new()
                },
            );
            row.push((best_tps, wbpt));
        }
        let (seq_tps, seq_w) = row[0];
        let (bat_tps, bat_w) = row[1];
        // exact m-fold amortization: the batched step charges the weights
        // once where the sequential loop charges them per slot
        assert!(
            (bat_w * m as f64 - seq_w).abs() <= seq_w * 0.01,
            "weight bytes/token must fall as 1/slots at m={m} ({bat_w} vs {seq_w})"
        );
        // wall-clock is noisy on shared/single-core machines: hard-assert
        // only at m=8 where the amortization margin is widest, warn below
        if m == 8 {
            assert!(
                bat_tps > seq_tps,
                "batched decode must out-run sequential at m={m} \
                 ({bat_tps:.1} vs {seq_tps:.1} tk/s)"
            );
        } else if m >= 4 && bat_tps <= seq_tps {
            eprintln!(
                "warning: batched decode did not out-run sequential at m={m} \
                 ({bat_tps:.1} vs {seq_tps:.1} tk/s) — noisy host?"
            );
        }
    }
    println!("\nweight bytes/token falls as 1/slots on the batched path (codes/scales/A/B stream");
    println!("once per step); the sequential column re-reads the full model every slot.");
    Ok(())
}

/// Paged vs dense KV at the SAME byte budget: the dense baseline fits 4
/// full-capacity caches; the paged pool spends those bytes on pages and
/// admits as many slots as the workload's real sequence lengths allow.
fn paged_vs_dense(model: &str, stream: &TokenStream, n: usize) -> anyhow::Result<()> {
    let store = WeightStore::load(&ckpt(model, "fbquant", 4))?;
    let cfg = store.cfg.clone();
    let page_size = 16usize;
    let dense_slots = 4usize;
    let slot_bytes = 2 * cfg.n_layers * cfg.max_seq * cfg.n_heads * cfg.head_dim() * 4;
    let page_bytes = 2 * cfg.n_layers * page_size * cfg.n_heads * cfg.head_dim() * 4;
    let budget = dense_slots * slot_bytes;
    let n_pages = budget / page_bytes;
    // how many pages one request can pin at worst, over this workload
    let probe = serving_workload(stream, n);
    let worst_pages = probe
        .iter()
        .map(|r| (r.prompt.len() + r.max_new_tokens + page_size - 1) / page_size)
        .max()
        .unwrap_or(1);
    let paged_slots = (n_pages / worst_pages).max(1);

    println!(
        "\n=== serving: paged vs dense KV at a fixed {} budget ({model}, {n} reqs) ===",
        fbquant::util::human_bytes(budget)
    );
    println!(
        "{:<8} {:>6} {:>9} {:>10} {:>10} {:>9} {:>13} {:>11} {:>9}",
        "kv", "slots", "gen toks", "wall s", "gen tk/s", "peak occ", "peak kv bytes",
        "prefix hit", "cow"
    );
    println!("{}", "-".repeat(92));
    let mut peaks = Vec::new();
    for paged in [false, true] {
        let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
        let mut backend = if paged {
            NativeBackend::new(engine, "paged")
                .with_max_slots(paged_slots)
                .with_kv_pool(page_size, n_pages)
        } else {
            NativeBackend::new(engine, "dense").with_dense().with_max_slots(dense_slots)
        };
        let reqs = serving_workload(stream, n);
        let t0 = Instant::now();
        let (responses, metrics) =
            Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default())?;
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len(), n, "lost requests");
        let (peak_bytes, hits, cow) = match &metrics.kv_pool {
            Some(p) => (p.peak_pages_in_use * page_bytes, p.prefix_hits, p.cow_copies),
            None => (dense_slots * slot_bytes, 0, 0),
        };
        println!(
            "{:<8} {:>6} {:>9} {:>10.2} {:>10.1} {:>9} {:>13} {:>11} {:>9}",
            if paged { "paged" } else { "dense" },
            if paged { paged_slots } else { dense_slots },
            metrics.tokens_generated,
            wall,
            metrics.tokens_generated as f64 / wall,
            metrics.peak_occupied,
            fbquant::util::human_bytes(peak_bytes),
            hits,
            cow,
        );
        peaks.push(metrics.peak_occupied);
    }
    assert!(
        paged_slots > dense_slots && peaks[1] > peaks[0],
        "paged KV must admit strictly more slots than dense at the same budget \
         ({paged_slots} vs {dense_slots} slots, peak {} vs {})",
        peaks[1],
        peaks[0]
    );
    println!(
        "\nsame {} of KV: dense admits {dense_slots} slots, the paged pool admits {paged_slots} \
         (worst-case {worst_pages} pages/request) — {:.1}x the concurrency.",
        fbquant::util::human_bytes(budget),
        paged_slots as f64 / dense_slots as f64
    );
    Ok(())
}

/// Templated workload: a shared 48-token prompt prefix + unique 16-token
/// suffix per request. Admissions after the first map the template's
/// pages from the prefix cache instead of re-running prefill over them.
fn prefix_reuse_demo(model: &str, stream: &TokenStream) -> anyhow::Result<()> {
    let store = WeightStore::load(&ckpt(model, "fbquant", 4))?;
    let toks = stream.tokens();
    let template: Vec<u32> = toks[..48].iter().map(|&b| b as u32).collect();
    let mut rng = Pcg64::seeded(0x7e417);
    let n = 12usize;
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n {
        let mut prompt = template.clone();
        let start = rng.below(toks.len() - 17);
        prompt.extend(toks[start..start + 16].iter().map(|&b| b as u32));
        reqs.push(GenRequest::new(i as u64 + 1, prompt, 16));
    }
    let total_prompt: usize = reqs.iter().map(|r| r.prompt.len()).sum();

    let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
    let mut backend = NativeBackend::new(engine, "prefix").with_max_slots(8);
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default())?;
    assert_eq!(responses.len(), n);
    let pool = metrics.kv_pool.expect("paged backend reports pool stats");
    println!(
        "\n=== serving: prefix reuse on a templated workload \
         ({model}, {n} reqs, shared 48-token template) ==="
    );
    println!(
        "prefix cache: {} hits / {} admissions, {} of {} prompt tokens served from shared \
         pages ({:.0}%), {} copy-on-write page copies, peak {} pages",
        pool.prefix_hits,
        pool.prefix_lookups,
        pool.prefix_tokens_reused,
        total_prompt,
        100.0 * pool.prefix_tokens_reused as f64 / total_prompt as f64,
        pool.cow_copies,
        pool.peak_pages_in_use,
    );
    assert!(
        pool.prefix_hits >= n - 1,
        "every admission after the first should hit the template prefix"
    );
    Ok(())
}

/// Self-speculative serving through the coordinator: the same greedy
/// workload decoded plain (K=0) and with K bare-branch drafts per slot
/// per step — outputs are token-identical, only the weight stream per
/// committed token changes.
fn speculative_serving(model: &str, stream: &TokenStream, n: usize) -> anyhow::Result<()> {
    let store = WeightStore::load(&ckpt(model, "fbquant", 4))?;
    println!(
        "\n=== serving: self-speculative (draft = bare branch) vs plain decode \
         ({model}, {n} reqs, greedy) ==="
    );
    println!(
        "{:<12} {:>9} {:>10} {:>10} {:>9} {:>10} {:>13}",
        "mode", "gen toks", "wall s", "gen tk/s", "accept", "tok/step", "W B/token"
    );
    println!("{}", "-".repeat(78));
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for spec_k in [0usize, 2, 4] {
        let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
        let mut backend = NativeBackend::new(engine, "spec");
        if spec_k > 0 {
            backend = backend
                .with_speculative(SpeculativeConfig::new(spec_k, DraftMode::NoSub));
        }
        // serving_workload defaults to greedy sampling, which is what
        // the speculative path accelerates
        let reqs = serving_workload(stream, n);
        let t0 = Instant::now();
        let (responses, metrics) =
            Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default())?;
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len(), n, "lost requests");
        println!(
            "{:<12} {:>9} {:>10.2} {:>10.1} {:>9.2} {:>10.2} {:>13}",
            if spec_k == 0 { "plain".to_string() } else { format!("spec K={spec_k}") },
            metrics.tokens_generated,
            wall,
            metrics.tokens_generated as f64 / wall,
            metrics.spec_acceptance_rate(),
            if spec_k == 0 { 1.0 } else { metrics.spec_tokens_per_step() },
            fbquant::util::human_bytes(metrics.weight_bytes_per_token() as usize),
        );
        outputs.push(responses.into_iter().map(|r| r.tokens).collect());
    }
    for k in 1..outputs.len() {
        assert_eq!(outputs[0], outputs[k], "speculative serving changed greedy output");
    }
    println!("\ngreedy outputs are token-identical across K; accepted drafts commit without");
    println!("re-streaming the verifier weights per token (charged once per step).");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if !have_artifacts() {
        eprintln!("fig7: run `make artifacts` first");
        return Ok(());
    }
    let model = if fast() { "llamoid-tiny" } else { "llamoid-small" };
    let stream = TokenStream::load(&artifacts().join("data/corpus_val.fbqw"))?;
    let prompt: Vec<u32> = stream.tokens()[..192].iter().map(|&b| b as u32).collect();
    let decode = 64;
    let reps = if fast() { 2 } else { 4 };

    println!("\n=== Fig 7: token throughput ({model}, prefill {} + decode {decode}, batch 1) ===",
             prompt.len());
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>12} {:>12}",
        "impl", "decode tk/s", "e2e tk/s", "norm.", "bytes/tok", "proj. tk/s*"
    );
    println!("{}", "-".repeat(76));

    let cases: Vec<(&str, &str, u8, SubMode)> = vec![
        ("FP32", "fp", 4, SubMode::None),
        ("INT4", "rtn", 4, SubMode::None),
        ("INT4-Sub", "fbquant", 4, SubMode::Unfused),
        ("INT4-FBQuant", "fbquant", 4, SubMode::Fused),
    ];
    // projection: a weight-bandwidth-bound edge device at 20 GB/s (the
    // paper's regime — our toy weights are cache-resident on CPU, so the
    // measured FP-vs-INT4 column is compute-bound; see EXPERIMENTS.md)
    const EDGE_BW: f64 = 20e9;
    let mut fp_tps = 0f64;
    for (name, method, bits, mode) in cases {
        let (dtps, etps, bpt) = throughput(model, method, bits, mode, &prompt, decode, reps)?;
        if name == "FP32" {
            fp_tps = dtps;
        }
        println!(
            "{:<14} {:>12.1} {:>12.1} {:>8.2} {:>12} {:>12.1}",
            name,
            dtps,
            etps,
            dtps / fp_tps,
            fbquant::util::human_bytes(bpt as usize),
            EDGE_BW / bpt
        );
    }
    println!("\n*projected decode tk/s on a 20 GB/s memory-bound edge device (bytes/token");
    println!(" measured from the kernel traffic counters — the regime of the paper's Fig 7).");
    println!("paper (3090, Llama2-7B): FP16 48 tk/s, INT4-Sub 46, INT4 ~64, INT4-FBQuant 61.");

    let n = if fast() { 12 } else { 24 };
    let serve_model = if fast() { "llamoid-tiny" } else { model };
    batched_vs_sequential(serve_model, &stream)?;
    serving_comparison(serve_model, &stream, n)?;
    paged_vs_dense(serve_model, &stream, n)?;
    prefix_reuse_demo(serve_model, &stream)?;
    speculative_serving(serve_model, &stream, n)?;
    Ok(())
}
